//! The `fpspatial` command-line interface, as a library module so tests
//! can drive `Args::parse` + dispatch in-process (`tests/cli_e2e.rs`).
//!
//! ```text
//! fpspatial compile <file.dsl> [-o out] [--name mod] [--emit sv|netlist]
//!                              [--report] [--with-lib]
//! fpspatial compile --filter median --fmt 10,5 --filter fp_sobel --fmt 7,6
//!                              [--emit sv|netlist] ...   # cascade emission
//! fpspatial run <filter> [--format f16] [--mode exact|poly]
//!                        [--exec scalar|batched|tiled:N|streaming:N]
//!                        [--input in.pgm] [--output out.pgm] [--size WxH]
//! fpspatial run --dsl a.dsl --filter median ...   # repeatable: a fused chain
//! fpspatial verify [--artifacts DIR]        # sim vs PJRT bit-exactness
//! fpspatial bench <table1|fig11|latency> [--full]
//! fpspatial pipeline [--filter median] [--dsl file.dsl] [--net file.net]
//!                    [--frames 16] [--workers 2] [--size WxH] [--exec ...]
//!                    [--deadline-ms N] [--on-overload block|drop-newest|drop-oldest]
//! fpspatial serve [--streams 4] [--frames 32] [--workers 4] [--size WxH]
//!                 [--filter median | --dsl file.dsl | --net file.net]
//!                 [--deadline-ms N] [--on-overload ...] [--expect-healthy]
//! fpspatial optimize [--filter ... | --dsl ... | --net file.net] [--fuse]
//!                    [--auto-fmt psnr=60|ulp=512] [--budget dsp=N,lut=N]
//!                    [--beam 4] [--size WxH] [-o pareto.json]
//! fpspatial resources [--filter conv3x3] [--format f16]
//! ```
//!
//! `optimize` runs the plan optimizer ([`crate::opt`]): `--fuse`
//! composes adjacent linear convolutions into one stage (with a signed
//! resource/latency delta and a *measured* accuracy drift), `--auto-fmt`
//! searches per-stage `(m, e)` formats against a PSNR / max-ulp target
//! and prints the Pareto front.  The same two flags ride along on
//! `run` / `pipeline` / `serve` to execute the optimized plan directly.
//!
//! `--exec` selects the execution plan ([`crate::pipeline::ExecPlan`]) —
//! every plan is bit-identical; `--batched` survives as the legacy alias
//! for `--exec batched`.  `--deadline-ms` and `--on-overload` configure
//! the session's supervision contract ([`crate::pipeline::SessionConfig`]):
//! a per-frame deadline and what to do when the streaming in-flight
//! budget is full.
//!
//! `--filter` and `--dsl` are **repeatable**: giving several (in any mix)
//! compiles one [`CompiledPipeline`] executed in one fused streaming
//! pass, e.g.
//! `fpspatial pipeline --dsl median.dsl --dsl sobel.dsl`.  Stage order is
//! the flag order on the command line.  A `--fmt m,e` (or `f16` /
//! `m10e5`) flag immediately after a stage flag overrides *that stage's*
//! format, making the chain mixed-precision: an explicit converter is
//! inserted at every boundary where the formats differ.  The same
//! binding rule covers the CNN-shaped stage flags: `--stride N`
//! subsamples the *preceding* stage's output on an `N×N` grid, and
//! `--pool k,s` appends a `k×k`/stride-`s` max-pool stage right after
//! the stage it follows.  `pipeline --net file.net` loads the whole
//! layer stack from a descriptor file instead
//! ([`crate::pipeline::load_net`]).
//!
//! (Hand-rolled argument parsing — the offline crate set has no clap.)

use std::collections::HashMap;
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::bench;
use crate::coordinator::synth_sequence;
use crate::dsl;
use crate::filters::{FilterKind, HwFilter};
use crate::fpcore::{format as fpformat, FloatFormat, OpMode};
use crate::opt::{self, ParetoPoint, SearchConfig};
use crate::pipeline::{
    load_net, CompiledPipeline, ExecPlan, FrameServer, OverloadPolicy, Pipeline, ServerEvent,
    SessionConfig,
};
use crate::resources::{estimate, Usage, ZYBO_Z7_20};
#[cfg(feature = "pjrt")]
use crate::runtime::Runtime;
use crate::video::{Frame, StageGeometry};

/// One `--filter <name>` / `--dsl <path>` / `--pool k,s` occurrence, in
/// CLI order — several of them form a chain.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum StageSel {
    Builtin(String),
    Dsl(String),
    /// `--pool k,s`: a `k×k` max-pool with output stride `s`, appended
    /// right after the stage it binds to.
    Pool { k: usize, stride: usize },
}

/// Minimal flag parser: positionals + `--key value` + boolean `--flag`,
/// plus the ordered repeatable chain flags (`--filter` / `--dsl`) with
/// their per-stage `--fmt` format overrides.
pub struct Args {
    positional: Vec<String>,
    flags: HashMap<String, String>,
    /// Ordered `--filter`/`--dsl` occurrences (chain stages).  The flags
    /// map additionally keeps the *last* value of each, so single-filter
    /// code paths keep working unchanged.
    stages: Vec<StageSel>,
    /// Per-stage format overrides, parallel to `stages`: a `--fmt m,e`
    /// (or `f16` / `m10e5`) flag binds to the *preceding* `--filter` /
    /// `--dsl` occurrence.
    stage_fmts: Vec<Option<String>>,
    /// Per-stage output strides, parallel to `stages`: a `--stride N`
    /// flag binds to the *preceding* `--filter`/`--dsl` occurrence
    /// (pool stages carry their stride in `--pool k,s` instead).
    stage_strides: Vec<Option<usize>>,
}

const BOOL_FLAGS: &[&str] =
    &["report", "full", "help", "with-lib", "batched", "expect-healthy", "fuse"];

impl Args {
    pub fn parse(argv: &[String]) -> Result<Args> {
        let mut positional = Vec::new();
        let mut flags = HashMap::new();
        let mut stages = Vec::new();
        let mut stage_fmts: Vec<Option<String>> = Vec::new();
        let mut stage_strides: Vec<Option<usize>> = Vec::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(name) = a.strip_prefix("--") {
                if BOOL_FLAGS.contains(&name) {
                    flags.insert(name.to_string(), "true".to_string());
                } else {
                    // value-taking flag: the next token must exist and must
                    // not itself be a flag (catches `run median --size`)
                    match argv.get(i + 1) {
                        Some(v) if !v.starts_with('-') => {
                            match name {
                                "filter" => {
                                    stages.push(StageSel::Builtin(v.clone()));
                                    stage_fmts.push(None);
                                    stage_strides.push(None);
                                }
                                "dsl" => {
                                    stages.push(StageSel::Dsl(v.clone()));
                                    stage_fmts.push(None);
                                    stage_strides.push(None);
                                }
                                "pool" => {
                                    if stages.is_empty() {
                                        bail!(
                                            "--pool binds after the preceding --filter/--dsl \
                                             stage flag; none given yet"
                                        );
                                    }
                                    let (k, s) = v.split_once(',').with_context(|| {
                                        format!(
                                            "--pool takes k,s (window and stride, e.g. \
                                             --pool 2,2), got {v:?}"
                                        )
                                    })?;
                                    let k: usize = k.trim().parse().with_context(|| {
                                        format!("--pool window must be an integer, got {k:?}")
                                    })?;
                                    let s: usize = s.trim().parse().with_context(|| {
                                        format!("--pool stride must be an integer, got {s:?}")
                                    })?;
                                    stages.push(StageSel::Pool { k, stride: s });
                                    stage_fmts.push(None);
                                    stage_strides.push(None);
                                }
                                "stride" => match stage_strides.last_mut() {
                                    None => bail!(
                                        "--stride binds to the preceding --filter/--dsl stage \
                                         flag; none given yet"
                                    ),
                                    Some(Some(prev)) => bail!(
                                        "stage already has a stride ({prev}); give one \
                                         --stride per stage"
                                    ),
                                    Some(slot) => {
                                        if matches!(stages.last(), Some(StageSel::Pool { .. })) {
                                            bail!(
                                                "a pool stage takes its stride inside --pool k,s; \
                                                 --stride binds to --filter/--dsl stages"
                                            );
                                        }
                                        *slot = Some(v.parse().with_context(|| {
                                            format!("--stride expects an integer, got {v:?}")
                                        })?);
                                    }
                                },
                                "fmt" => match stage_fmts.last_mut() {
                                    None => bail!(
                                        "--fmt binds to the preceding --filter/--dsl stage \
                                         flag; none given yet (for a single filter use \
                                         --format)"
                                    ),
                                    Some(Some(prev)) => bail!(
                                        "stage already has a format override ({prev}); \
                                         give one --fmt per stage"
                                    ),
                                    Some(slot) => *slot = Some(v.clone()),
                                },
                                _ => {}
                            }
                            flags.insert(name.to_string(), v.clone());
                            i += 1;
                        }
                        _ => bail!("flag --{name} expects a value (e.g. `--{name} <value>`)"),
                    }
                }
            } else if let Some(name) = a.strip_prefix('-') {
                match name {
                    "o" => match argv.get(i + 1) {
                        Some(v) if !v.starts_with('-') => {
                            flags.insert("output".to_string(), v.clone());
                            i += 1;
                        }
                        _ => bail!("flag -o expects an output path"),
                    },
                    "h" => {
                        flags.insert("help".to_string(), "true".to_string());
                    }
                    other => bail!("unknown flag -{other} (long options use `--{other}`)"),
                }
            } else {
                positional.push(a.clone());
            }
            i += 1;
        }
        Ok(Args { positional, flags, stages, stage_fmts, stage_strides })
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    /// The ordered chain stage selections (`--filter`/`--dsl` flags).
    pub fn stage_selections(&self) -> &[StageSel] {
        &self.stages
    }

    /// Per-stage `--fmt` overrides, parallel to [`Args::stage_selections`].
    pub fn stage_formats(&self) -> &[Option<String>] {
        &self.stage_fmts
    }

    /// Per-stage `--stride` overrides, parallel to
    /// [`Args::stage_selections`].
    pub fn stage_strides(&self) -> &[Option<usize>] {
        &self.stage_strides
    }
}

fn parse_format(args: &Args) -> Result<FloatFormat> {
    let key = args.get("format").unwrap_or("f16");
    fpformat::lookup(key)
        .with_context(|| format!("unknown format {key:?} (f16/f24/f32/f48/f64 or m10e5)"))
}

/// `--format` only when explicitly given — DSL programs carry their own
/// `use float(m, e);` directive, which the flag overrides.
fn parse_format_override(args: &Args) -> Result<Option<FloatFormat>> {
    match args.get("format") {
        None => Ok(None),
        Some(_) => parse_format(args).map(Some),
    }
}

/// Resolve one stage's format override: its own `--fmt` flag if bound,
/// else the global `--format` flag (explicitly given only).
fn parse_stage_format(fmt_key: Option<&str>, args: &Args) -> Result<Option<FloatFormat>> {
    match fmt_key {
        Some(k) => Ok(Some(fpformat::lookup(k).with_context(|| {
            format!("unknown --fmt {k:?} (f16/f24/f32/f48/f64, m10e5 or m,e)")
        })?)),
        None => parse_format_override(args),
    }
}

/// Load a DSL program from `path` into a runtime filter (module name =
/// file stem).
fn load_dsl_filter(path: &str, fmt: Option<FloatFormat>) -> Result<HwFilter> {
    let src = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
    let name = std::path::Path::new(path)
        .file_stem()
        .and_then(|s| s.to_str())
        .unwrap_or("dsl_filter")
        .to_string();
    HwFilter::from_dsl(&src, &name, fmt).with_context(|| format!("compiling {path}"))
}

/// Build a single stage from one selection (with its own `--fmt` key
/// and optional `--stride` override).
fn load_stage(
    sel: &StageSel,
    fmt_key: Option<&str>,
    stride: Option<usize>,
    args: &Args,
) -> Result<HwFilter> {
    let fmt = parse_stage_format(fmt_key, args)?;
    let hw = match sel {
        StageSel::Dsl(path) => load_dsl_filter(path, fmt)?,
        StageSel::Builtin(name) => {
            let kind =
                FilterKind::by_name(name).with_context(|| format!("unknown filter {name}"))?;
            HwFilter::new(kind, fmt.map_or_else(|| parse_format(args), Ok)?)
                .with_context(|| format!("`{name}` cannot stream through the netlist runtime"))?
        }
        StageSel::Pool { k, stride } => {
            HwFilter::max_pool(fmt.map_or_else(|| parse_format(args), Ok)?, *k, *stride)?
        }
    };
    Ok(match stride {
        Some(s) => hw.with_stride(s),
        None => hw,
    })
}

/// Build the (possibly mixed-precision, possibly strided) execution
/// plan from the repeatable `--filter`/`--dsl`/`--pool` flags and their
/// per-stage `--fmt`/`--stride` overrides — a single filter is a plan
/// of one stage.
fn build_plan(args: &Args, mode: OpMode) -> Result<CompiledPipeline> {
    let stages: Vec<HwFilter> = args
        .stages
        .iter()
        .zip(args.stage_fmts.iter().zip(&args.stage_strides))
        .map(|(sel, (fmt, stride))| load_stage(sel, fmt.as_deref(), *stride, args))
        .collect::<Result<_>>()?;
    Pipeline::from_stages(stages).compile(mode)
}

/// Resolve the execution plan: `--exec scalar|batched|tiled:N|streaming:N`,
/// with `--batched` kept as the legacy alias for `--exec batched`.
fn parse_exec(args: &Args, default: ExecPlan) -> Result<ExecPlan> {
    match (args.get("exec"), args.get("batched").is_some()) {
        (Some(_), true) => bail!(
            "--exec and --batched are mutually exclusive (--batched is the legacy \
             alias for `--exec batched`)"
        ),
        (Some(spec), false) => ExecPlan::parse(spec),
        (None, true) => Ok(ExecPlan::Batched),
        (None, false) => Ok(default),
    }
}

fn parse_size(args: &Args, default: (usize, usize)) -> Result<(usize, usize)> {
    match args.get("size") {
        None => Ok(default),
        Some(s) => {
            let (w, h) = s.split_once('x').context("--size WxH")?;
            Ok((w.parse()?, h.parse()?))
        }
    }
}

/// The session supervision contract from `--deadline-ms N` and
/// `--on-overload block|drop-newest|drop-oldest` (both optional; the
/// defaults are no deadline and classic blocking backpressure).
fn parse_session_config(args: &Args) -> Result<SessionConfig> {
    let mut cfg = SessionConfig::new();
    if let Some(ms) = args.get("deadline-ms") {
        let ms: u64 = ms
            .parse()
            .map_err(|_| anyhow::anyhow!("--deadline-ms expects milliseconds, got {ms:?}"))?;
        if ms == 0 {
            bail!("--deadline-ms needs a positive millisecond count");
        }
        cfg = cfg.deadline(Duration::from_millis(ms));
    }
    if let Some(p) = args.get("on-overload") {
        cfg = cfg.overload(OverloadPolicy::parse(p)?);
    }
    Ok(cfg)
}

fn parse_mode(args: &Args) -> Result<OpMode> {
    match args.get("mode").unwrap_or("exact") {
        "exact" => Ok(OpMode::Exact),
        "poly" => Ok(OpMode::Poly),
        other => bail!("unknown mode {other:?} (exact|poly)"),
    }
}

/// Parse and dispatch one CLI invocation (everything after the binary
/// name).  The process entry point (`main.rs`) and the end-to-end tests
/// call this.
pub fn run(argv: &[String]) -> Result<()> {
    if argv.is_empty() {
        print_help();
        return Ok(());
    }
    let cmd = argv[0].clone();
    let args = Args::parse(&argv[1..])?;
    match cmd.as_str() {
        "compile" => cmd_compile(&args),
        "run" => cmd_run(&args),
        "verify" => cmd_verify(&args),
        "bench" => cmd_bench(&args),
        "pipeline" => cmd_pipeline(&args),
        "serve" => cmd_serve(&args),
        "optimize" => cmd_optimize(&args),
        "resources" => cmd_resources(&args),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => bail!("unknown command {other:?} (try `fpspatial help`)"),
    }
}

fn print_help() {
    println!(
        "fpspatial — custom floating-point spatial filters (paper reproduction)

USAGE:
  fpspatial compile <file.dsl> [-o out] [--name mod] [--emit sv|netlist|kernel]
                    [--report] [--with-lib]
  fpspatial compile --filter median --fmt 10,5 --filter fp_sobel --fmt 7,6
                    [--emit sv|netlist|kernel] [-o out] [--name mod] [--report]
  fpspatial run <conv3x3|conv5x5|median|nlfilter|fp_sobel|hls_sobel>
  fpspatial run --dsl <file.dsl>            # compiled DSL program as the filter
                [--format f16|f24|f32|f48|f64|mMeE] [--mode exact|poly]
                [--input in.pgm] [--output out.pgm] [--size WxH]
                [--exec scalar|batched|tiled:N|streaming:N]
  fpspatial verify [--artifacts DIR]
  fpspatial bench <table1|fig11|latency> [--full]
  fpspatial pipeline [--filter median | --dsl <file.dsl> | --net <file.net>]
                     [--frames 16] [--workers 2] [--size WxH] [--exec ...]
                     [--deadline-ms N] [--on-overload block|drop-newest|drop-oldest]
  fpspatial serve [--streams 4] [--frames 32] [--workers 4] [--size WxH]
                  [--filter median | --dsl <file.dsl> | --net <file.net>]
                  [--deadline-ms N] [--on-overload ...] [--expect-healthy]
  fpspatial optimize [--filter ... | --dsl ... | --net <file.net>] [--fuse]
                     [--auto-fmt psnr=60|ulp=512] [--budget dsp=N,lut=N,bram-bits=N]
                     [--beam 4] [--line-width 1920] [--size WxH] [-o pareto.json]
  fpspatial resources [--filter conv3x3] [--format f16]

Execution plans (--exec): every plan produces bit-identical output.
  scalar       serial, scalar engine (the reference shape)
  batched      serial, lane-batched engine (single-thread fast path)
  tiled:N      one frame sharded into N row bands (intra-frame)
  streaming:N  N-worker frame pipeline, in-order delivery (inter-frame;
               the `pipeline` command's default, with N = --workers)
`--batched` is the legacy alias for `--exec batched` (under `pipeline`,
whose streaming default is already lane-batched, it keeps the default
plan); `--workers` and an explicit `--exec` are mutually exclusive.

Supervision (`run` and `pipeline`): sessions contain worker panics
(typed error naming the frame; the worker is respawned) and reject
non-finite input pixels.  `--deadline-ms N` bounds each frame's
submit->delivery latency; `--on-overload` picks what happens when the
streaming in-flight budget (workers + reorder window) is full:
  block        wait for capacity (default; bounded by the deadline)
  drop-newest  drop the incoming frame, never block the submitter
  drop-oldest  retract the oldest unclaimed frame (freshest data wins)
Drops, deadline misses and worker restarts are reported in the
`pipeline` metrics line.

Serving many streams: `fpspatial serve` schedules --streams independent
sessions (same filter plan) over ONE shared worker pool — round-robin
across streams, per-stream bounded queues and overload policy, shared
frame-buffer recycling.  Each stream's output stays in-order and
bit-identical to running it alone; a worker panic on one stream never
touches the others.  Prints a per-stream table plus the aggregate rate;
`--expect-healthy` exits nonzero if any fault or worker restart was
observed (the CI smoke contract).

Multi-filter chains: `--filter` and `--dsl` repeat (any mix, CLI order =
stage order), fusing the stages into ONE streaming pass — stage i+1's
window generator consumes stage i's rows directly, no intermediate
frames.  A `--fmt m,e` flag right after a stage flag overrides that
stage's format (mixed-precision chains insert explicit converters at
every boundary where formats differ).  CNN-shaped stages bind the same
way: `--stride N` right after a stage subsamples its output on an N×N
grid, and `--pool k,s` appends a k×k max-pool with output stride s
(relu/pool layers, `input channels=C` planes and per-layer formats can
also come from a `.net` descriptor via `pipeline --net`).  Examples:

  fpspatial pipeline --dsl median.dsl --dsl sobel.dsl --workers 4 --batched
  fpspatial run --filter median --fmt 10,5 --filter fp_sobel --fmt 7,6
  fpspatial run --filter conv3x3 --stride 2 --pool 2,2 --size 64x48
  fpspatial pipeline --net examples/net/vgg_block.net --exec streaming:4
  fpspatial compile --filter median --fmt 10,5 --filter fp_sobel --fmt 7,6 \\
                    --emit sv -o cascade.sv

The plan optimizer: `optimize --fuse` composes adjacent stride-1
same-format linear convolutions into one wider stage (3x3 after 3x3
becomes one 5x5) and reports the honest resource/latency deltas plus a
MEASURED accuracy drift vs the unfused sequence; `optimize --auto-fmt
psnr=60` (or `ulp=N`) searches per-stage (m,e) assignments over a
25-format lattice — uniform sweep + beam narrowing, every candidate
scored by really running it — and prints the Pareto front, the uniform
m10e5 baseline, and the cheapest feasible choice (front also written to
pareto.json).  `--budget dsp=N,lut=N,bram-bits=N` adds resource
ceilings.  The same `--fuse`/`--auto-fmt` flags on `run`/`pipeline`/
`serve` execute the optimized plan directly:

  fpspatial optimize --net examples/net/vgg_block.net --fuse --auto-fmt psnr=50
  fpspatial run --filter conv3x3 --filter conv3x3 --fuse
  fpspatial pipeline --net examples/net/vgg_block.net --auto-fmt psnr=60

The DSL workflow: write a window program (see examples/dsl/), then
`compile` emits pipelined SystemVerilog (+ --report schedule/resources;
`--emit netlist` dumps the scheduled netlist as JSON, `--emit kernel`
prints the fused direct-threaded software kernel instead), while
`run --dsl` / `pipeline --dsl` stream frames through the same compiled
netlist in software.  `compile` on stage flags emits ONE cascade top
module instantiating every stage plus the inter-stage fmt_converters."
    );
}

fn cmd_compile(args: &Args) -> Result<()> {
    let emit = args.get("emit").unwrap_or("sv");
    if !matches!(emit, "sv" | "netlist" | "kernel") {
        bail!("unknown --emit {emit:?} (sv|netlist|kernel)");
    }
    if !args.stages.is_empty() {
        return cmd_compile_chain(args, emit);
    }
    let path = args
        .positional
        .first()
        .context("usage: fpspatial compile <file.dsl> | compile --filter/--dsl ... (a cascade)")?;
    let src = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
    let default_name = std::path::Path::new(path)
        .file_stem()
        .and_then(|s| s.to_str())
        .unwrap_or("fp_top")
        .to_string();
    let name = args.get("name").unwrap_or(&default_name);

    let t0 = Instant::now();
    let compiled = dsl::compile(&src, name)?;
    if emit == "kernel" {
        // dump the fused direct-threaded kernel the software hot path runs
        let mode = parse_mode(args)?;
        let kernel = crate::sim::compile(&compiled.netlist, mode);
        print!("{}", kernel.dump());
        let s = kernel.stats();
        println!(
            "compiled {path}: {} tape steps -> {} fused instrs ({} slots -> {}), in {:.2?}",
            s.steps_in,
            s.instrs_out,
            s.slots_in,
            s.slots_out,
            t0.elapsed()
        );
        if args.get("report").is_some() {
            print_compiled_report(&compiled);
        }
        return Ok(());
    }
    if emit == "netlist" {
        // JSON dump of the scheduled netlist for external tooling
        use crate::util::json::{num, obj, s, Json};
        let window = match &compiled.window {
            None => Json::Null,
            Some(w) => obj(vec![
                ("height", num(w.height as f64)),
                ("width", num(w.width as f64)),
            ]),
        };
        let json = obj(vec![
            ("name", s(&compiled.name)),
            ("window", window),
            ("netlist", compiled.netlist.to_json()),
        ]);
        let out_path = args
            .get("output")
            .map(str::to_string)
            .unwrap_or_else(|| format!("{name}.netlist.json"));
        std::fs::write(&out_path, json.to_string())
            .with_context(|| format!("writing {out_path}"))?;
        println!(
            "compiled {path} -> {out_path}: {} operators, latency {} cycles, in {:.2?}",
            compiled.netlist.nodes.len(),
            compiled.netlist.total_latency(),
            t0.elapsed()
        );
        if args.get("report").is_some() {
            print_compiled_report(&compiled);
        }
        return Ok(());
    }
    let sv = dsl::sverilog::generate(&compiled);
    let elapsed = t0.elapsed();

    let out_path = args
        .get("output")
        .map(str::to_string)
        .unwrap_or_else(|| format!("{name}.sv"));
    std::fs::write(&out_path, &sv).with_context(|| format!("writing {out_path}"))?;
    if args.get("with-lib").is_some() {
        // emit the self-contained operator library next to the top module
        let lib = dsl::svlib::generate_library(compiled.fmt);
        let lib_path = lib_path_for(&out_path, "_fplib");
        std::fs::write(&lib_path, &lib).with_context(|| format!("writing {lib_path}"))?;
        println!("wrote operator library {lib_path} ({} lines)", lib.lines().count());
    }

    println!(
        "compiled {path} -> {out_path}: {} DSL lines -> {} SV lines in {:.2?}",
        src.lines().count(),
        sv.lines().count(),
        elapsed
    );
    if args.get("report").is_some() {
        print_compiled_report(&compiled);
    }
    Ok(())
}

/// Schedule + resource report for one compiled program (`--report`).
fn print_compiled_report(compiled: &dsl::Compiled) {
    let nl = &compiled.netlist;
    println!("  format        : {}", compiled.fmt);
    println!("  operators     : {}", nl.nodes.len());
    println!("  total latency : {} cycles", nl.total_latency());
    println!("  delay regs    : {}", nl.delay_registers());
    if let Some(w) = &compiled.window {
        println!(
            "  window        : {}x{} (line buffers: {})",
            w.height,
            w.width,
            w.height - 1
        );
    }
    let window = compiled
        .window
        .as_ref()
        .map(|w| (StageGeometry::rect(w.height, w.width), 1920));
    let usage = estimate(nl, window);
    print_usage_line("Zybo Z7-20", &usage);
}

/// Derive a sibling library path from the main output path: insert
/// `suffix` before a trailing `.sv`, or append `{suffix}.sv` when the
/// user's `-o` has no `.sv` extension (a plain `replace(".sv", ...)`
/// would silently return the *same* path and overwrite the module).
fn lib_path_for(out_path: &str, suffix: &str) -> String {
    match out_path.strip_suffix(".sv") {
        Some(stem) => format!("{stem}{suffix}.sv"),
        None => format!("{out_path}{suffix}.sv"),
    }
}

/// Compile a (possibly mixed-precision) filter cascade given as
/// repeatable `--filter`/`--dsl` stage flags with per-stage `--fmt`
/// overrides: `--emit sv` writes ONE top module instantiating every
/// stage plus the inter-stage `fmt_converter` blocks; `--emit netlist`
/// writes the JSON dump of every stage's scheduled netlist plus the
/// converter list.
fn cmd_compile_chain(args: &Args, emit: &str) -> Result<()> {
    if let Some(p) = args.positional.first() {
        bail!(
            "both a positional program ({p}) and --filter/--dsl stage flags given — \
             pick one way of selecting what to compile"
        );
    }
    let t0 = Instant::now();
    let chain = build_plan(args, parse_mode(args)?)?;
    let default_name = {
        let names: Vec<String> = chain
            .stages()
            .iter()
            .map(|hw| dsl::sverilog::sv_ident(hw.name()))
            .collect();
        format!("{}_cascade", names.join("_"))
    };
    let name = args.get("name").unwrap_or(&default_name).to_string();

    match emit {
        "kernel" => {
            print!("{}", chain.kernel_dump());
            println!(
                "compiled {} stage(s): fused kernels above, in {:.2?}",
                chain.len(),
                t0.elapsed()
            );
        }
        "netlist" => {
            let json = chain.netlist_json(&name);
            let out_path = args
                .get("output")
                .map(str::to_string)
                .unwrap_or_else(|| format!("{name}.netlist.json"));
            std::fs::write(&out_path, json.to_string())
                .with_context(|| format!("writing {out_path}"))?;
            println!(
                "compiled {} stage(s) -> {out_path}: cascade latency {} cycles, in {:.2?}",
                chain.len(),
                chain.datapath_latency(),
                t0.elapsed()
            );
        }
        _ => {
            let sv = chain.emit_sv(&name, (1920, 1080));
            let out_path = args
                .get("output")
                .map(str::to_string)
                .unwrap_or_else(|| format!("{name}.sv"));
            std::fs::write(&out_path, &sv).with_context(|| format!("writing {out_path}"))?;
            if args.get("with-lib").is_some() {
                // The operator blocks are width-parameterized, but the
                // poly ROM constants are *bit-encoded at a format* when
                // the library is generated — so a mixed cascade needs
                // one library per distinct stage format.  Module names
                // collide across libraries: compile each stage against
                // the library matching its format, one per elaboration.
                let mut seen: Vec<crate::fpcore::FloatFormat> = Vec::new();
                for hw in chain.stages() {
                    if !seen.contains(&hw.fmt) {
                        seen.push(hw.fmt);
                    }
                }
                let single = seen.len() == 1;
                for f in &seen {
                    let lib = dsl::svlib::generate_library(*f);
                    let lib_path = if single {
                        lib_path_for(&out_path, "_fplib")
                    } else {
                        lib_path_for(&out_path, &format!("_fplib_{}", f.name()))
                    };
                    std::fs::write(&lib_path, &lib)
                        .with_context(|| format!("writing {lib_path}"))?;
                    println!(
                        "wrote operator library {lib_path} ({} lines, ROMs fitted at {f})",
                        lib.lines().count()
                    );
                }
                if !single {
                    println!(
                        "note: module names collide across the {} libraries — \
                         elaborate each stage against the library matching its format",
                        seen.len()
                    );
                }
            }
            println!(
                "compiled cascade {} -> {out_path}: {} stage(s) -> {} SV lines in {:.2?}",
                chain.name(),
                chain.len(),
                sv.lines().count(),
                t0.elapsed()
            );
        }
    }
    if args.get("report").is_some() {
        print_chain_report(&chain, 1920);
    }
    Ok(())
}

/// One-line resource summary against the paper's board.
fn print_usage_line(label: &str, usage: &Usage) {
    let u = usage.utilization(ZYBO_Z7_20);
    println!(
        "  {label:<14}: {} LUT ({:.1}%), {} FF ({:.1}%), {:.1} BRAM36 ({:.1}%), {} DSP ({:.1}%) -> {}",
        usage.luts,
        u[0],
        usage.ffs,
        u[1],
        usage.bram36,
        u[2],
        usage.dsps,
        u[3],
        if usage.fits(ZYBO_Z7_20) { "fits" } else { "DOES NOT FIT" }
    );
}

/// Chain-wide latency + resource report (the `run`/`pipeline` chain
/// summary).
fn print_chain_report(chain: &CompiledPipeline, width: usize) {
    println!("  stages        : {}", chain.len());
    let converters = chain.converters();
    for (i, hw) in chain.stages().iter().enumerate() {
        println!(
            "    {:<12} [{}] {} window, datapath {} cycles",
            hw.name(),
            hw.fmt,
            hw.geom,
            hw.latency()
        );
        if let Some(Some(cvt)) = converters.get(i) {
            println!("    {:<12} {} ({} cycles)", "fmt_convert", cvt, cvt.latency());
        }
    }
    println!(
        "  latency       : {} datapath cycles; end-to-end at width {width}: {} cycles",
        chain.datapath_latency(),
        chain.pipeline_latency_cycles(width)
    );
    println!(
        "  line buffers  : {} bits total (the fused pass holds no intermediate frames)",
        chain.line_buffer_bits(width)
    );
    print_usage_line("Zybo Z7-20", &chain.resource_usage(width));
}

fn cmd_run(args: &Args) -> Result<()> {
    let mode = parse_mode(args)?;
    let (w, h) = parse_size(args, (128, 96))?;
    let frame = match args.get("input") {
        Some(p) => Frame::load_pgm(p)?,
        None => Frame::test_card(w, h),
    };
    let exec = parse_exec(args, ExecPlan::Scalar)?;
    let config = parse_session_config(args)?;

    // What to run: a compiled plan over the selected stages (a single
    // filter is a plan of one), or the fixed-point baseline (hls_sobel
    // has no custom-float netlist).
    enum Runner {
        Plan(Box<CompiledPipeline>),
        Fixed,
    }
    let runner = if !args.stages.is_empty() {
        if let Some(name) = args.positional.first() {
            bail!(
                "both `--filter`/`--dsl` flags and filter `{name}` given — pick one \
                 way of selecting filters"
            );
        }
        match &args.stages[..] {
            [StageSel::Builtin(name)] if name == "hls_sobel" => {
                parse_format_override(args)?;
                Runner::Fixed
            }
            _ => Runner::Plan(Box::new(apply_optimizations(build_plan(args, mode)?, args)?)),
        }
    } else {
        let name = args
            .positional
            .first()
            .context("usage: fpspatial run <filter> | fpspatial run --dsl <file.dsl>")?;
        if name == "hls_sobel" {
            // fixed-point q16.8: --format does not apply, but a given flag
            // is still validated so typos don't pass silently
            parse_format_override(args)?;
            Runner::Fixed
        } else {
            let kind =
                FilterKind::by_name(name).with_context(|| format!("unknown filter {name}"))?;
            let hw = HwFilter::new(kind, parse_format(args)?)?;
            Runner::Plan(Box::new(apply_optimizations(
                Pipeline::from_stages([hw]).compile(mode)?,
                args,
            )?))
        }
    };
    // usable errors (not panics) for frames the window cannot stream
    if let Runner::Plan(plan) = &runner {
        plan.check_frame(&frame)?;
    }
    let (name, fmt_label) = match &runner {
        Runner::Plan(plan) if plan.len() == 1 => {
            (plan.name().to_string(), plan.stages()[0].fmt.to_string())
        }
        Runner::Plan(plan) => (plan.name().to_string(), "per-stage".to_string()),
        Runner::Fixed => ("hls_sobel".to_string(), "q16.8".to_string()),
    };

    let t0 = Instant::now();
    let out = match &runner {
        Runner::Fixed => crate::filters::fixed::sobel_fixed_frame(&frame),
        Runner::Plan(plan) => plan.session_with(exec, config)?.process(&frame)?,
    };
    let dt = t0.elapsed();
    let mpix = (frame.width * frame.height) as f64 / dt.as_secs_f64() / 1e6;
    println!(
        "{name} [{fmt_label}] on {}x{}: {:.2?} ({mpix:.1} Mpx/s simulated{})",
        frame.width,
        frame.height,
        dt,
        match &runner {
            Runner::Plan(_) => format!(", exec {exec}"),
            Runner::Fixed => String::new(),
        }
    );
    if let Runner::Plan(plan) = &runner {
        if plan.len() >= 2 {
            print_chain_report(plan, frame.width);
        }
    }
    if let Some(p) = args.get("output") {
        out.save_pgm(p)?;
        println!("wrote {p}");
    }
    Ok(())
}

/// Bit-exactness: every golden artifact vs the cycle simulator.
#[cfg(feature = "pjrt")]
fn cmd_verify(args: &Args) -> Result<()> {
    let dir = args.get("artifacts").unwrap_or("artifacts");
    let rt = Runtime::new(dir)?;
    let golden: Vec<_> = rt
        .manifest()
        .iter()
        .filter(|e| e.set == "golden")
        .cloned()
        .collect();
    if golden.is_empty() {
        bail!("no golden artifacts in {dir} (run `make artifacts`)");
    }
    println!("verifying {} golden artifacts against the cycle simulator...", golden.len());
    let mut failures = 0;
    for entry in &golden {
        let fmt = FloatFormat::new(entry.mantissa.unwrap(), entry.exponent.unwrap());
        let frame = Frame::test_card(entry.width, entry.height);
        let exe = rt.load(entry)?;
        let kernel: Option<Vec<f64>> = if entry.filter.starts_with("conv") {
            let k = if entry.filter == "conv3x3" {
                crate::filters::conv::gaussian3x3()
            } else {
                crate::filters::conv::gaussian5x5()
            };
            Some(k)
        } else {
            None
        };
        let got = exe.run(&frame, kernel.as_deref())?;

        // simulate: quantize input like the L2 wrapper, then stream
        let qframe = Frame {
            width: frame.width,
            height: frame.height,
            data: frame.data.iter().map(|&v| crate::fpcore::quantize(v, fmt)).collect(),
        };
        // the plan's sequential oracle is the simulator-side reference
        let want = match entry.filter.as_str() {
            "conv3x3" | "conv5x5" => {
                let kq: Vec<f64> = kernel
                    .as_ref()
                    .unwrap()
                    .iter()
                    .map(|&v| crate::fpcore::quantize(v, fmt))
                    .collect();
                let kind = FilterKind::by_name(&entry.filter).unwrap();
                Pipeline::from_stages([HwFilter::with_kernel(kind, fmt, &kq)])
                    .compile(OpMode::Exact)?
                    .run_frame_sequential(&qframe)
            }
            other => {
                let kind = FilterKind::by_name(other).context("filter kind")?;
                Pipeline::from_stages([HwFilter::new(kind, fmt)?])
                    .compile(OpMode::Exact)?
                    .run_frame_sequential(&qframe)
            }
        };
        let excess = crate::runtime::golden_mismatch(&got, &want, &entry.filter, fmt.mantissa);
        let ok = excess == 0.0;
        if !ok {
            failures += 1;
        }
        let raw = got.max_abs_diff(&want);
        println!(
            "  {:<30} {}",
            entry.file,
            if ok && raw == 0.0 {
                "bit-exact".to_string()
            } else if ok {
                format!("within golden tolerance (max |d| = {raw:.3e})")
            } else {
                format!("MISMATCH (excess = {excess:.3e})")
            }
        );
    }
    if failures > 0 {
        bail!("{failures} artifacts mismatched");
    }
    println!("all golden artifacts bit-exact");
    Ok(())
}

/// Without the `pjrt` feature there is no XLA client to execute the
/// golden artifacts — fail with build instructions instead of silently
/// skipping the check.
#[cfg(not(feature = "pjrt"))]
fn cmd_verify(_args: &Args) -> Result<()> {
    bail!(
        "`fpspatial verify` executes the PJRT golden artifacts, which needs the \
         `pjrt` cargo feature (and the `xla` crate it pulls in): rebuild with \
         `cargo build --features pjrt` and run `make artifacts` first"
    )
}

fn cmd_bench(args: &Args) -> Result<()> {
    let which = args.positional.first().map(|s| s.as_str()).unwrap_or("table1");
    let full = args.get("full").is_some();
    match which {
        "table1" => {
            let fmt = parse_format(args)?;
            let rows = bench::table1::run(fmt, !full)?;
            println!("{}", bench::table1::render(&rows));
            if let Some(s) = bench::table1::headline_speedup(&rows) {
                println!(
                    "headline: hardware nlfilter is {s:.0}x software at 1080p (paper: ~810x)"
                );
            }
        }
        "fig11" => {
            let pts = bench::fig11::run();
            println!("{}", bench::fig11::render(&pts));
        }
        "latency" => {
            let fmt = parse_format(args)?;
            println!("datapath latencies at {fmt} (paper SIII):");
            for kind in [
                FilterKind::Conv3x3,
                FilterKind::Conv5x5,
                FilterKind::Median,
                FilterKind::Nlfilter,
                FilterKind::FpSobel,
            ] {
                let hw = HwFilter::new(kind, fmt)?;
                println!(
                    "  {:<10} lat = {:>2} cycles, {} operators, {} delay registers",
                    kind.name(),
                    hw.latency(),
                    hw.netlist.nodes.len(),
                    hw.netlist.delay_registers()
                );
            }
        }
        other => bail!("unknown bench {other:?} (table1|fig11|latency)"),
    }
    Ok(())
}

fn cmd_pipeline(args: &Args) -> Result<()> {
    let frames: usize = args.get("frames").unwrap_or("16").parse()?;
    let workers: usize = args.get("workers").unwrap_or("2").parse()?;
    let (w, h) = parse_size(args, (320, 240))?;
    let mode = parse_mode(args)?;
    // --workers configures the default streaming plan only; an explicit
    // --exec carries its own worker count, so giving both is ambiguous
    if args.get("exec").is_some() && args.get("workers").is_some() {
        bail!(
            "--workers and --exec are mutually exclusive: give the worker count in the \
             plan itself (e.g. `--exec streaming:{workers}` or `--exec tiled:{workers}`)"
        );
    }
    // Default: the inter-frame worker pipeline this command always ran.
    // Legacy `pipeline --batched` meant that same pipeline with
    // lane-batched engines — streaming sessions are always lane-batched,
    // so the alias maps back onto the default plan (workers intact).
    let exec = if args.get("exec").is_some() {
        parse_exec(args, ExecPlan::streaming(workers))?
    } else {
        ExecPlan::streaming(workers)
    };
    let config = parse_session_config(args)?;
    let seq = synth_sequence(w, h, frames);

    let plan = apply_optimizations(resolve_plan(args, mode)?, args)?;
    if let Some(f) = seq.first() {
        plan.check_frame(f)?;
    }
    let fmt_label = plan_fmt_label(&plan);
    let mut session = plan.session_with(exec, config)?;
    let m = session.process_sequence(seq, |_, _| {})?;
    println!(
        "{} [{fmt_label}] {w}x{h}: {} frames in {:.2?} -> {:.2} FPS ({:.1} Mpx/s), latency mean {:.2?} / p99 {:.2?} / max {:.2?}, exec {exec}",
        plan.name(),
        m.delivered,
        m.elapsed,
        m.fps(),
        m.pixel_rate(w, h) / 1e6,
        m.mean_latency,
        m.p99_latency,
        m.max_latency,
    );
    if m.dropped + m.deadline_misses + m.worker_restarts > 0 {
        // rates above cover delivered frames only; name both counts here
        println!(
            "  supervision   : {} submitted / {} delivered; {} dropped, {} deadline misses, \
             {} worker restarts",
            m.submitted(),
            m.delivered,
            m.dropped,
            m.deadline_misses,
            m.worker_restarts
        );
    }
    if plan.len() >= 2 {
        print_chain_report(&plan, w);
    }
    Ok(())
}

/// Resolve the filter plan shared by `pipeline` and `serve`: a `--net`
/// descriptor, the repeatable stage flags, or a single `--filter`
/// (default: median).
fn resolve_plan(args: &Args, mode: OpMode) -> Result<CompiledPipeline> {
    if let Some(path) = args.get("net") {
        if !args.stages.is_empty() {
            bail!(
                "--net describes the whole layer stack; don't mix it with \
                 --filter/--dsl/--pool stage flags"
            );
        }
        return load_net(path)?.compile(mode);
    }
    if !args.stages.is_empty() {
        return build_plan(args, mode);
    }
    let name = args.get("filter").unwrap_or("median");
    let kind = FilterKind::by_name(name).with_context(|| format!("unknown filter {name}"))?;
    let hw = HwFilter::new(kind, parse_format(args)?)
        .with_context(|| format!("`{name}` cannot stream through the netlist pipeline"))?;
    Pipeline::from_stages([hw]).compile(mode)
}

/// Apply the opt-in plan optimizations shared by `run`/`pipeline`/
/// `serve`: `--fuse` composes adjacent linear convolutions (warn and
/// continue when nothing fuses — e.g. relu/pool boundaries), then
/// `--auto-fmt psnr=N|ulp=N` re-stages every stage at the cheapest
/// format assignment the search found for that target.
fn apply_optimizations(mut plan: CompiledPipeline, args: &Args) -> Result<CompiledPipeline> {
    if args.get("fuse").is_some() {
        match plan.fused() {
            Ok((fused, report)) => {
                println!(
                    "fused {} -> {} stage(s): datapath {} -> {} cycles, max drift {:.2} ulp, \
                     psnr delta {:.1} dB",
                    report.stages_before,
                    report.stages_after,
                    report.latency_before,
                    report.latency_after,
                    report.accuracy.max_ulp,
                    report.accuracy.psnr,
                );
                plan = fused;
            }
            Err(e) => println!("--fuse: nothing fused ({e:#})"),
        }
    }
    if args.get("auto-fmt").is_some() {
        let cfg = parse_auto_fmt(args)?;
        let frames = eval_frames(&plan, 96, 64)?;
        let res = opt::search_formats(&plan, &frames, &cfg)?;
        match res.chosen {
            Some(p) => {
                println!(
                    "auto-fmt: {} ({} LUTs, {} DSPs, psnr {:.1} dB, max {:.1} ulp; \
                     {} assignments evaluated)",
                    p.format_names(),
                    p.luts,
                    p.dsps,
                    p.psnr,
                    p.max_ulp,
                    res.evaluated
                );
                plan = opt::restage_plan(&plan, &p.formats)?;
            }
            None => println!(
                "auto-fmt: no format assignment met the target within the budget; \
                 keeping the declared formats"
            ),
        }
    }
    Ok(plan)
}

/// `--auto-fmt psnr=60` / `--auto-fmt ulp=512` (comma-combinable) plus
/// the optional `--budget dsp=N,lut=N,bram-bits=N`, `--beam N` and
/// `--line-width N` knobs into a [`SearchConfig`].
fn parse_auto_fmt(args: &Args) -> Result<SearchConfig> {
    let mut cfg = SearchConfig::default();
    let spec = args.get("auto-fmt").unwrap_or("");
    for part in spec.split(',').filter(|p| !p.trim().is_empty()) {
        let (k, v) = part.split_once('=').with_context(|| {
            format!("--auto-fmt takes psnr=DB and/or ulp=N (comma-separated), got {part:?}")
        })?;
        match k.trim() {
            "psnr" => {
                cfg.psnr_target = Some(v.trim().parse().with_context(|| {
                    format!("--auto-fmt psnr expects decibels, got {v:?}")
                })?)
            }
            "ulp" => {
                cfg.max_ulp_target = Some(v.trim().parse().with_context(|| {
                    format!("--auto-fmt ulp expects a count, got {v:?}")
                })?)
            }
            other => bail!("unknown --auto-fmt key {other:?} (psnr|ulp)"),
        }
    }
    if cfg.psnr_target.is_none() && cfg.max_ulp_target.is_none() {
        bail!("--auto-fmt needs a target, e.g. --auto-fmt psnr=60 or --auto-fmt ulp=512");
    }
    if let Some(b) = args.get("budget") {
        for part in b.split(',').filter(|p| !p.trim().is_empty()) {
            let (k, v) = part.split_once('=').with_context(|| {
                format!("--budget takes dsp=N,lut=N,bram-bits=N, got {part:?}")
            })?;
            let n: u64 = v.trim().parse().with_context(|| {
                format!("--budget {} expects a count, got {v:?}", k.trim())
            })?;
            match k.trim() {
                "dsp" => cfg.budget.dsps = Some(n),
                "lut" => cfg.budget.luts = Some(n),
                "bram-bits" => cfg.budget.bram_bits = Some(n),
                other => bail!("unknown --budget key {other:?} (dsp|lut|bram-bits)"),
            }
        }
    }
    if let Some(bw) = args.get("beam") {
        cfg.beam = bw.parse().context("--beam expects a width (integer >= 1)")?;
    }
    if let Some(lw) = args.get("line-width") {
        cfg.line_width = lw.parse().context("--line-width expects a pixel count")?;
    }
    Ok(cfg)
}

/// The deterministic accuracy-evaluation frames, keeping only those the
/// plan's window chain can stream end to end.
fn eval_frames(plan: &CompiledPipeline, w: usize, h: usize) -> Result<Vec<Frame>> {
    let frames: Vec<Frame> = opt::reference_frames(w, h)
        .into_iter()
        .filter(|f| plan.check_frame(f).is_ok())
        .collect();
    if frames.is_empty() {
        bail!(
            "no {w}x{h} evaluation frame fits the plan's window chain \
             (give a larger --size WxH)"
        );
    }
    Ok(frames)
}

/// `fpspatial optimize`: run the plan optimizer on any `--filter`/
/// `--dsl`/`--net` pipeline — `--fuse` prints the fusion report,
/// `--auto-fmt psnr=N|ulp=N [--budget ...]` runs the per-stage format
/// search, prints the Pareto front plus the uniform-m10e5 baseline
/// comparison, and writes the front to `pareto.json` (`-o` overrides).
fn cmd_optimize(args: &Args) -> Result<()> {
    let mode = parse_mode(args)?;
    let (w, h) = parse_size(args, (96, 64))?;
    let auto = args.get("auto-fmt").is_some();
    if !auto && args.get("fuse").is_none() {
        bail!(
            "optimize needs --fuse and/or --auto-fmt, e.g. \
             `fpspatial optimize --net layers.net --fuse --auto-fmt psnr=60`"
        );
    }
    let mut plan = resolve_plan(args, mode)?;
    let t0 = Instant::now();
    if args.get("fuse").is_some() {
        match plan.fused() {
            Ok((fused, report)) => {
                print!("{}", report.summary());
                plan = fused;
            }
            Err(e) => println!("--fuse: nothing fused ({e:#})"),
        }
    }
    if !auto {
        return Ok(());
    }
    let cfg = parse_auto_fmt(args)?;
    let frames = eval_frames(&plan, w, h)?;
    let res = opt::search_formats(&plan, &frames, &cfg)?;
    println!(
        "Pareto front over {} ({} stage(s), {} assignments evaluated in {:.2?}):",
        plan.name(),
        plan.len(),
        res.evaluated,
        t0.elapsed()
    );
    println!(
        "  {:<44} {:>8} {:>9} {:>9} {:>5} {:>10}",
        "formats", "psnr dB", "max ulp", "LUTs", "DSPs", "BRAM bits"
    );
    for p in &res.front {
        print_pareto_row(p, "");
    }
    let baseline =
        opt::evaluate_point(&plan, &frames, &vec![FloatFormat::new(10, 5); plan.len()], cfg.line_width)?;
    print_pareto_row(&baseline, " (uniform m10e5 baseline)");
    match &res.chosen {
        Some(p) => {
            println!("chosen: {}", p.format_names());
            // "beats" = strictly cheaper on LUTs while meeting the
            // accuracy target (the baseline may overshoot the target —
            // matching IT would forfeit legitimate area savings)
            let psnr_ok = match cfg.psnr_target {
                Some(t) => p.psnr >= t.min(baseline.psnr),
                None => p.psnr >= baseline.psnr,
            };
            let beats = p.luts < baseline.luts && cfg.feasible(p) && psnr_ok;
            println!(
                "chosen beats uniform m10e5 baseline: {}",
                if beats {
                    format!(
                        "yes ({} vs {} LUTs at psnr {:.1} vs {:.1} dB)",
                        p.luts, baseline.luts, p.psnr, baseline.psnr
                    )
                } else {
                    format!(
                        "no ({} vs {} LUTs, psnr {:.1} vs {:.1} dB)",
                        p.luts, baseline.luts, p.psnr, baseline.psnr
                    )
                }
            );
        }
        None => println!("chosen: none (no assignment met the target within the budget)"),
    }
    let out = args.get("output").unwrap_or("pareto.json");
    write_pareto_json(out, &res, &baseline)?;
    println!("wrote {out}");
    Ok(())
}

fn print_pareto_row(p: &ParetoPoint, suffix: &str) {
    println!(
        "  {:<44} {:>8.1} {:>9.1} {:>9} {:>5} {:>10}{suffix}",
        p.format_names(),
        p.psnr,
        p.max_ulp,
        p.luts,
        p.dsps,
        p.bram_bits
    );
}

fn write_pareto_json(path: &str, res: &opt::SearchResult, baseline: &ParetoPoint) -> Result<()> {
    use crate::util::json::{num, obj, s, Json};
    let point = |p: &ParetoPoint| {
        obj(vec![
            ("formats", Json::Arr(p.formats.iter().map(|f| s(&f.name())).collect())),
            ("psnr", num(p.psnr)),
            ("max_ulp", num(p.max_ulp)),
            ("luts", num(p.luts as f64)),
            ("dsps", num(p.dsps as f64)),
            ("bram_bits", num(p.bram_bits as f64)),
        ])
    };
    let json = obj(vec![
        ("front", Json::Arr(res.front.iter().map(point).collect())),
        (
            "chosen",
            match &res.chosen {
                Some(p) => point(p),
                None => Json::Null,
            },
        ),
        ("baseline_m10e5", point(baseline)),
        ("evaluated", num(res.evaluated as f64)),
    ]);
    std::fs::write(path, json.to_string()).with_context(|| format!("writing {path}"))
}

fn plan_fmt_label(plan: &CompiledPipeline) -> String {
    if plan.len() == 1 {
        plan.stages()[0].fmt.to_string()
    } else {
        "per-stage".to_string()
    }
}

/// `fpspatial serve`: drive N independent streams of synthetic frames
/// through ONE shared worker pool ([`FrameServer`]) and report
/// per-stream + aggregate metrics.  `--expect-healthy` makes it the CI
/// smoke contract: any fault event or worker restart exits nonzero.
fn cmd_serve(args: &Args) -> Result<()> {
    let streams: usize = args.get("streams").unwrap_or("4").parse()?;
    let frames: usize = args.get("frames").unwrap_or("32").parse()?;
    let workers: usize = args.get("workers").unwrap_or("4").parse()?;
    if streams == 0 {
        bail!("--streams needs at least one stream");
    }
    if frames == 0 {
        bail!("--frames needs at least one frame per stream");
    }
    let (w, h) = parse_size(args, (320, 240))?;
    let mode = parse_mode(args)?;
    let config = parse_session_config(args)?;
    let plan = apply_optimizations(resolve_plan(args, mode)?, args)?;
    plan.check_frame(&Frame::new(w, h))?;

    let mut builder = FrameServer::builder(workers);
    for _ in 0..streams {
        builder = builder.stream(&plan, config.clone());
    }
    let mut server = builder.build()?;
    let senders: Vec<_> = (0..streams).map(|s| server.sender(s)).collect::<Result<_>>()?;

    let mut delivered = vec![0u64; streams];
    let mut faults: Vec<(usize, String)> = Vec::new();
    thread::scope(|scope| {
        for (s, sender) in senders.into_iter().enumerate() {
            scope.spawn(move || {
                for i in 0..frames {
                    // distinct deterministic content per stream and frame
                    let seed = (s * frames + i) as u64;
                    if !sender.send(Frame::noise(w, h, seed)) {
                        break;
                    }
                }
            });
        }
        server.run(|ev| match ev {
            ServerEvent::Frame { stream, frame, .. } => {
                delivered[stream] += 1;
                Some(frame) // hand the buffer back for recycling
            }
            ServerEvent::Fault { stream, error } => {
                faults.push((stream, error.to_string()));
                None
            }
        })
    })?;

    let fmt_label = plan_fmt_label(&plan);
    println!(
        "{} [{fmt_label}] {w}x{h}: {streams} streams x {frames} frames over {workers} shared workers",
        plan.name()
    );
    for s in 0..streams {
        let m = server.metrics(s);
        println!(
            "  stream {s:>3}: {}/{} delivered, latency mean {:.2?} / p99 {:.2?}; {} dropped, {} deadline misses, {} worker restarts",
            m.delivered,
            m.submitted(),
            m.mean_latency,
            m.p99_latency,
            m.dropped,
            m.deadline_misses,
            m.worker_restarts
        );
    }
    let a = server.aggregate();
    println!(
        "  aggregate : {} delivered in {:.2?} -> {:.2} FPS ({:.1} Mpx/s aggregate), p99 {:.2?}; {} dropped, {} deadline misses, {} worker restarts",
        a.delivered,
        a.elapsed,
        a.fps(),
        a.pixel_rate(w, h) / 1e6,
        a.p99_latency,
        a.dropped,
        a.deadline_misses,
        a.worker_restarts
    );
    for (s, err) in &faults {
        println!("  fault on stream {s}: {err}");
    }
    if args.get("expect-healthy").is_some() {
        if a.worker_restarts > 0 {
            bail!("--expect-healthy: {} worker restart(s) on a healthy run", a.worker_restarts);
        }
        if !faults.is_empty() {
            bail!("--expect-healthy: {} fault event(s) on a healthy run", faults.len());
        }
    }
    Ok(())
}

fn cmd_resources(args: &Args) -> Result<()> {
    let fmt = parse_format(args)?;
    let name = args.get("filter").unwrap_or("conv3x3");
    let usage = if name == "hls_sobel" {
        crate::resources::hls_sobel_usage(1920)
    } else {
        let kind = FilterKind::by_name(name).with_context(|| format!("unknown filter {name}"))?;
        let hw = HwFilter::new(kind, fmt)?;
        estimate(&hw.netlist, Some((hw.geom, 1920)))
    };
    let u = usage.utilization(ZYBO_Z7_20);
    println!("{name} [{fmt}] on Zybo Z7-20 (1080p line buffers):");
    println!("  LUTs   : {:>7}  ({:.2}%)", usage.luts, u[0]);
    println!("  FFs    : {:>7}  ({:.2}%)", usage.ffs, u[1]);
    println!("  BRAM36 : {:>7.1}  ({:.2}%)", usage.bram36, u[2]);
    println!("  DSPs   : {:>7}  ({:.2}%)", usage.dsps, u[3]);
    println!("  => {}", if usage.fits(ZYBO_Z7_20) { "fits" } else { "DOES NOT FIT" });
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::{Args, StageSel};

    fn sv(args: &[&str]) -> Vec<String> {
        args.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn positionals_flags_and_bools() {
        let a = Args::parse(&sv(&["median", "--size", "64x48", "--batched"])).unwrap();
        assert_eq!(a.positional, vec!["median"]);
        assert_eq!(a.get("size"), Some("64x48"));
        assert_eq!(a.get("batched"), Some("true"));
    }

    #[test]
    fn trailing_value_flag_is_an_error_naming_the_flag() {
        let err = Args::parse(&sv(&["median", "--size"])).unwrap_err();
        assert!(err.to_string().contains("--size"), "{err}");
    }

    #[test]
    fn value_flag_followed_by_flag_is_an_error() {
        let err = Args::parse(&sv(&["--size", "--batched"])).unwrap_err();
        assert!(err.to_string().contains("--size"), "{err}");
    }

    #[test]
    fn unknown_single_dash_flag_is_an_error_naming_the_flag() {
        let err = Args::parse(&sv(&["run", "-x"])).unwrap_err();
        assert!(err.to_string().contains("-x"), "{err}");
    }

    #[test]
    fn dash_o_and_dash_h_still_work() {
        let a = Args::parse(&sv(&["file.dsl", "-o", "out.sv"])).unwrap();
        assert_eq!(a.get("output"), Some("out.sv"));
        let h = Args::parse(&sv(&["-h"])).unwrap();
        assert_eq!(h.get("help"), Some("true"));
        assert!(Args::parse(&sv(&["-o"])).is_err());
    }

    #[test]
    fn repeated_filter_and_dsl_flags_preserve_order() {
        let a = Args::parse(&sv(&[
            "--dsl", "median.dsl", "--filter", "fp_sobel", "--dsl", "blur.dsl",
        ]))
        .unwrap();
        assert_eq!(
            a.stage_selections(),
            &[
                StageSel::Dsl("median.dsl".to_string()),
                StageSel::Builtin("fp_sobel".to_string()),
                StageSel::Dsl("blur.dsl".to_string()),
            ]
        );
        // the flags map keeps the last of each for single-filter paths
        assert_eq!(a.get("dsl"), Some("blur.dsl"));
        assert_eq!(a.get("filter"), Some("fp_sobel"));
    }

    #[test]
    fn trailing_chain_flag_is_an_error() {
        let err = Args::parse(&sv(&["--dsl", "a.dsl", "--filter"])).unwrap_err();
        assert!(err.to_string().contains("--filter"), "{err}");
    }

    #[test]
    fn lib_path_never_collides_with_the_module_path() {
        assert_eq!(super::lib_path_for("cascade.sv", "_fplib"), "cascade_fplib.sv");
        // -o without a .sv extension must still get a distinct file
        assert_eq!(super::lib_path_for("cascade", "_fplib"), "cascade_fplib.sv");
        assert_eq!(
            super::lib_path_for("out.sv", "_fplib_m10e5"),
            "out_fplib_m10e5.sv"
        );
    }

    #[test]
    fn per_stage_fmt_binds_to_the_preceding_stage() {
        let a = Args::parse(&sv(&[
            "--filter", "median", "--fmt", "10,5", "--dsl", "sobel.dsl", "--filter",
            "conv3x3", "--fmt", "f24",
        ]))
        .unwrap();
        assert_eq!(a.stage_selections().len(), 3);
        assert_eq!(
            a.stage_formats(),
            &[Some("10,5".to_string()), None, Some("f24".to_string())]
        );
    }

    #[test]
    fn fmt_before_any_stage_is_an_error() {
        let err = Args::parse(&sv(&["--fmt", "10,5", "--filter", "median"])).unwrap_err();
        assert!(err.to_string().contains("--filter/--dsl"), "{err}");
    }

    #[test]
    fn exec_flag_and_batched_alias() {
        use crate::pipeline::ExecPlan;
        let a = Args::parse(&sv(&["median", "--exec", "tiled:3"])).unwrap();
        assert_eq!(
            super::parse_exec(&a, ExecPlan::Scalar).unwrap(),
            ExecPlan::Tiled { workers: 3 }
        );
        // --batched survives as the alias for --exec batched
        let a = Args::parse(&sv(&["median", "--batched"])).unwrap();
        assert_eq!(super::parse_exec(&a, ExecPlan::Scalar).unwrap(), ExecPlan::Batched);
        // neither flag: the command default applies
        let a = Args::parse(&sv(&["median"])).unwrap();
        assert_eq!(super::parse_exec(&a, ExecPlan::streaming(2)).unwrap(), ExecPlan::streaming(2));
        // both at once is a usable conflict error
        let a = Args::parse(&sv(&["median", "--exec", "batched", "--batched"])).unwrap();
        let err = super::parse_exec(&a, ExecPlan::Scalar).unwrap_err();
        assert!(err.to_string().contains("mutually exclusive"), "{err}");
    }

    #[test]
    fn session_config_flags_parse() {
        use crate::pipeline::OverloadPolicy;
        use std::time::Duration;
        let a = Args::parse(&sv(&[
            "median", "--deadline-ms", "16", "--on-overload", "drop-newest",
        ]))
        .unwrap();
        let cfg = super::parse_session_config(&a).unwrap();
        assert_eq!(cfg.deadline, Some(Duration::from_millis(16)));
        assert_eq!(cfg.overload, OverloadPolicy::DropNewest);
        // defaults: no deadline, blocking backpressure
        let cfg = super::parse_session_config(&Args::parse(&sv(&["median"])).unwrap()).unwrap();
        assert_eq!(cfg.deadline, None);
        assert_eq!(cfg.overload, OverloadPolicy::Block);
        // usable errors naming the flag / the bad value
        let a = Args::parse(&sv(&["median", "--deadline-ms", "soon"])).unwrap();
        let err = super::parse_session_config(&a).unwrap_err();
        assert!(err.to_string().contains("--deadline-ms"), "{err}");
        let a = Args::parse(&sv(&["median", "--deadline-ms", "0"])).unwrap();
        assert!(super::parse_session_config(&a).is_err());
        let a = Args::parse(&sv(&["median", "--on-overload", "shed"])).unwrap();
        let err = super::parse_session_config(&a).unwrap_err();
        assert!(err.to_string().contains("shed"), "{err}");
    }

    #[test]
    fn stride_and_pool_bind_to_the_preceding_stage() {
        let a = Args::parse(&sv(&[
            "--filter", "conv3x3", "--stride", "2", "--pool", "2,2", "--fmt", "10,5",
        ]))
        .unwrap();
        assert_eq!(
            a.stage_selections(),
            &[
                StageSel::Builtin("conv3x3".to_string()),
                StageSel::Pool { k: 2, stride: 2 },
            ]
        );
        assert_eq!(a.stage_strides(), &[Some(2), None]);
        // the --fmt after --pool binds to the pool stage itself
        assert_eq!(a.stage_formats(), &[None, Some("10,5".to_string())]);
    }

    #[test]
    fn stride_before_any_stage_is_rejected() {
        let err = Args::parse(&sv(&["--stride", "2", "--filter", "median"])).unwrap_err();
        assert!(err.to_string().contains("--filter/--dsl"), "{err}");
    }

    #[test]
    fn two_strides_for_one_stage_are_rejected() {
        let err =
            Args::parse(&sv(&["--filter", "median", "--stride", "2", "--stride", "3"]))
                .unwrap_err();
        assert!(err.to_string().contains("one --stride per stage"), "{err}");
    }

    #[test]
    fn non_numeric_stride_is_rejected() {
        let err = Args::parse(&sv(&["--filter", "median", "--stride", "fast"])).unwrap_err();
        assert!(err.to_string().contains("--stride"), "{err}");
    }

    #[test]
    fn stride_on_a_pool_stage_is_rejected() {
        let err = Args::parse(&sv(&["--filter", "median", "--pool", "2,2", "--stride", "2"]))
            .unwrap_err();
        assert!(err.to_string().contains("--pool k,s"), "{err}");
    }

    #[test]
    fn pool_before_any_stage_and_bad_pool_values_are_rejected() {
        let err = Args::parse(&sv(&["--pool", "2,2", "--filter", "median"])).unwrap_err();
        assert!(err.to_string().contains("--pool"), "{err}");
        // missing the stride half
        let err = Args::parse(&sv(&["--filter", "median", "--pool", "2"])).unwrap_err();
        assert!(err.to_string().contains("k,s"), "{err}");
        // non-numeric window
        let err = Args::parse(&sv(&["--filter", "median", "--pool", "two,2"])).unwrap_err();
        assert!(err.to_string().contains("integer"), "{err}");
    }

    #[test]
    fn optimizer_flags_parse() {
        let a = Args::parse(&sv(&["--filter", "conv3x3", "--fuse", "--auto-fmt", "psnr=60"]))
            .unwrap();
        assert_eq!(a.get("fuse"), Some("true"));
        assert_eq!(a.get("auto-fmt"), Some("psnr=60"));
        let cfg = super::parse_auto_fmt(&a).unwrap();
        assert_eq!(cfg.psnr_target, Some(60.0));
        assert_eq!(cfg.max_ulp_target, None);
        // a malformed spec and a missing target are usable errors
        let a = Args::parse(&sv(&["--auto-fmt", "fast"])).unwrap();
        assert!(super::parse_auto_fmt(&a).is_err());
        // budget keys bind per axis
        let a = Args::parse(&sv(&["--auto-fmt", "ulp=512", "--budget", "dsp=40,lut=9000"]))
            .unwrap();
        let cfg = super::parse_auto_fmt(&a).unwrap();
        assert_eq!(cfg.max_ulp_target, Some(512.0));
        assert_eq!(cfg.budget.dsps, Some(40));
        assert_eq!(cfg.budget.luts, Some(9000));
        let a = Args::parse(&sv(&["--auto-fmt", "psnr=50", "--budget", "carry=1"])).unwrap();
        let err = super::parse_auto_fmt(&a).unwrap_err();
        assert!(err.to_string().contains("carry"), "{err}");
    }

    #[test]
    fn two_fmt_for_one_stage_is_an_error() {
        let err =
            Args::parse(&sv(&["--filter", "median", "--fmt", "10,5", "--fmt", "7,6"]))
                .unwrap_err();
        assert!(err.to_string().contains("one --fmt per stage"), "{err}");
    }
}
