//! PJRT runtime: loads the AOT-lowered JAX/Pallas artifacts
//! (`artifacts/*.hlo.txt`) and executes them on the XLA CPU client.
//!
//! Python never runs on this path — `make artifacts` lowers every
//! (filter × format × resolution) variant once at build time; this module
//! compiles the HLO text (`HloModuleProto::from_text_file` → the text
//! parser reassigns the 64-bit instruction ids jax ≥ 0.5 emits, which
//! xla_extension 0.5.1 would otherwise reject) and executes with
//! f64 literals.
//!
//! The executed artifacts serve two roles:
//! * **golden reference** — the custom-float variants must match the Rust
//!   cycle simulator bit-for-bit (integration test `pjrt_golden`);
//! * **software baseline** — the native-f64 variants are the vectorized
//!   scipy-equivalent rows of Table I.
//!
//! The XLA-backed pieces (`Runtime` / `Executable`) are gated behind
//! the `pjrt` cargo feature: the offline build environment does not
//! vendor the `xla` crate, so the default build ships only the pure
//! helpers (manifest parsing, golden tolerances) and the
//! `fault-injection` chaos hooks (the `fault` module).

#[cfg(feature = "fault-injection")]
pub mod fault;

use std::path::Path;
#[cfg(feature = "pjrt")]
use std::path::PathBuf;

#[cfg(feature = "pjrt")]
use anyhow::bail;
use anyhow::{Context, Result};

use crate::util::json::Json;
use crate::video::Frame;

/// One artifact from `artifacts/manifest.json`.
#[derive(Debug, Clone)]
pub struct ManifestEntry {
    pub file: String,
    pub filter: String,
    /// Format key (`"f16"`, ...) or `None` for the native-f64 software set.
    pub format: Option<String>,
    pub mantissa: Option<u32>,
    pub exponent: Option<u32>,
    pub height: usize,
    pub width: usize,
    pub set: String,
}

/// Parse `artifacts/manifest.json`.
pub fn load_manifest(dir: impl AsRef<Path>) -> Result<Vec<ManifestEntry>> {
    let path = dir.as_ref().join("manifest.json");
    let text = std::fs::read_to_string(&path)
        .with_context(|| format!("reading {} (run `make artifacts`)", path.display()))?;
    let v = Json::parse(&text)?;
    let arr = v.as_arr().context("manifest is not an array")?;
    arr.iter()
        .map(|e| {
            Ok(ManifestEntry {
                file: e.get("file").and_then(Json::as_str).context("file")?.to_string(),
                filter: e.get("filter").and_then(Json::as_str).context("filter")?.to_string(),
                format: e.get("format").and_then(Json::as_str).map(str::to_string),
                mantissa: e.get("mantissa").and_then(Json::as_f64).map(|v| v as u32),
                exponent: e.get("exponent").and_then(Json::as_f64).map(|v| v as u32),
                height: e.get("height").and_then(Json::as_usize).context("height")?,
                width: e.get("width").and_then(Json::as_usize).context("width")?,
                set: e.get("set").and_then(Json::as_str).unwrap_or("").to_string(),
            })
        })
        .collect()
}

/// A compiled artifact ready to execute.
#[cfg(feature = "pjrt")]
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub entry: ManifestEntry,
}

/// The PJRT CPU runtime.
#[cfg(feature = "pjrt")]
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: Vec<ManifestEntry>,
}

#[cfg(feature = "pjrt")]
impl Runtime {
    /// Create a CPU PJRT client and read the artifact manifest.
    pub fn new(artifacts_dir: impl AsRef<Path>) -> Result<Self> {
        let dir = artifacts_dir.as_ref().to_path_buf();
        let manifest = load_manifest(&dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self { client, dir, manifest })
    }

    pub fn manifest(&self) -> &[ManifestEntry] {
        &self.manifest
    }

    /// Find a manifest entry.
    pub fn find(
        &self,
        filter: &str,
        format: Option<&str>,
        height: usize,
        width: usize,
    ) -> Option<&ManifestEntry> {
        self.manifest.iter().find(|e| {
            e.filter == filter
                && e.format.as_deref() == format
                && e.height == height
                && e.width == width
        })
    }

    /// Load + compile an artifact by manifest entry.
    pub fn load(&self, entry: &ManifestEntry) -> Result<Executable> {
        let path = self.dir.join(&entry.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parsing {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", entry.file))?;
        Ok(Executable { exe, entry: entry.clone() })
    }

    /// Convenience: find + load.
    pub fn load_filter(
        &self,
        filter: &str,
        format: Option<&str>,
        height: usize,
        width: usize,
    ) -> Result<Executable> {
        let entry = self
            .find(filter, format, height, width)
            .with_context(|| {
                format!("no artifact for {filter} fmt={format:?} {height}x{width}")
            })?
            .clone();
        self.load(&entry)
    }
}

#[cfg(feature = "pjrt")]
impl Executable {
    /// Execute on a frame.  Conv filters additionally take the flat kernel
    /// coefficients (`ksize²` doubles).
    pub fn run(&self, frame: &Frame, kernel: Option<&[f64]>) -> Result<Frame> {
        if frame.height != self.entry.height || frame.width != self.entry.width {
            bail!(
                "frame is {}x{} but artifact {} is {}x{}",
                frame.height,
                frame.width,
                self.entry.file,
                self.entry.height,
                self.entry.width
            );
        }
        let x = xla::Literal::vec1(&frame.data)
            .reshape(&[frame.height as i64, frame.width as i64])?;
        let mut args = vec![x];
        let needs_kernel = self.entry.filter.starts_with("conv");
        match (needs_kernel, kernel) {
            (true, Some(k)) => args.push(xla::Literal::vec1(k)),
            (true, None) => bail!("{} needs kernel coefficients", self.entry.filter),
            (false, Some(_)) => bail!("{} takes no kernel", self.entry.filter),
            (false, None) => {}
        }
        let result = self.exe.execute::<xla::Literal>(&args)?[0][0].to_literal_sync()?;
        // jax lowered with return_tuple=True: unwrap the 1-tuple
        let out = result.to_tuple1()?;
        let data = out.to_vec::<f64>()?;
        if data.len() != frame.data.len() {
            bail!("output size {} != {}", data.len(), frame.data.len());
        }
        Ok(Frame { width: frame.width, height: frame.height, data })
    }
}

/// Golden-comparison contract (DESIGN.md §6).
///
/// Filters built only from *correctly rounded* IEEE ops (add, mul, div,
/// sqrt, max/min — conv, median, sobel) are **bit-exact** between the JAX
/// artifact and the Rust simulator.  `log2`/`exp2` are library
/// approximations that differ between XLA CPU and libm by up to ~21 f64
/// ulps, so `nlfilter` is compared to within a few ulps *of the custom
/// format* (a boundary-straddling rounding can flip one format ulp).
/// Formats with m ≥ 52 quantize by clamping only, so raw f64 library
/// differences show through — compared at 1e-12 relative.
pub fn golden_tolerance(filter: &str, mantissa: u32, want: f64) -> f64 {
    let transcendental = filter == "nlfilter";
    match (transcendental, mantissa >= 52) {
        (false, false) => 0.0,
        (true, false) => 4.0 * want.abs() * 2.0_f64.powi(-(mantissa as i32)) + 1e-300,
        (_, true) => want.abs() * 1e-12 + 1e-300,
    }
}

/// Max violation of the golden tolerance across a frame (0.0 == pass).
pub fn golden_mismatch(got: &Frame, want: &Frame, filter: &str, mantissa: u32) -> f64 {
    got.data
        .iter()
        .zip(&want.data)
        .map(|(&g, &w)| ((g - w).abs() - golden_tolerance(filter, mantissa, w)).max(0.0))
        .fold(0.0, f64::max)
}

// These tests exercise the artifacts directory (`make artifacts`) and
// the XLA client, neither of which exist in the default offline build.
#[cfg(all(test, feature = "pjrt"))]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn manifest_loads() {
        let m = load_manifest(artifacts_dir()).unwrap();
        assert!(m.len() >= 40, "{}", m.len());
        assert!(m.iter().any(|e| e.filter == "nlfilter" && e.format.as_deref() == Some("f16")));
        assert!(m.iter().any(|e| e.format.is_none() && e.set.starts_with("software")));
    }

    #[test]
    fn golden_median_runs_and_matches_sim() {
        let rt = Runtime::new(artifacts_dir()).unwrap();
        let entry = rt.find("median", Some("f16"), 96, 128).unwrap().clone();
        let exe = rt.load(&entry).unwrap();
        let frame = Frame::test_card(128, 96);
        let got = exe.run(&frame, None).unwrap();

        // bit-exact against the cycle simulator's functional engine
        use crate::fpcore::{quantize, FloatFormat, OpMode};
        let fmt = FloatFormat::new(10, 5);
        let qframe = Frame {
            width: frame.width,
            height: frame.height,
            data: frame.data.iter().map(|&v| quantize(v, fmt)).collect(),
        };
        let plan = crate::pipeline::Pipeline::new()
            .builtin(crate::filters::FilterKind::Median)
            .format(fmt)
            .compile(OpMode::Exact)
            .unwrap();
        let want = plan.run_frame_sequential(&qframe);
        assert_eq!(got.data, want.data, "sim vs PJRT mismatch");
    }
}
