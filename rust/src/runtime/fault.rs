//! Fault injection for the session runtime (`--features fault-injection`).
//!
//! A [`FaultScript`] is a deterministic chaos plan keyed by frame
//! sequence number: *panic while evaluating frame k*, *add latency to
//! frame k*, *corrupt frame k's pixels before validation*.  Sessions
//! carry an optional `Arc<FaultScript>`
//! ([`SessionConfig::with_faults`](crate::pipeline::SessionConfig)) and
//! fire the hooks at the exact points real faults would strike:
//!
//! * **panic** — inside the worker's `catch_unwind` boundary, after the
//!   frame was claimed (exercises capture → typed
//!   [`ExecError::WorkerPanicked`](crate::pipeline::ExecError) → respawn);
//! * **delay** — same place (exercises deadlines and overload policies);
//! * **corrupt** — at submission entry, before input validation
//!   (exercises [`ExecError::PoisonFrame`](crate::pipeline::ExecError)
//!   detection on genuinely non-finite data).
//!
//! Every hook is **one-shot**: it fires the first time its frame index is
//! seen and then disarms, so a respawned worker or a retried frame never
//! re-trips the same fault.  This module compiles only with the
//! `fault-injection` feature; production builds contain none of it.

use std::collections::HashMap;
use std::sync::Mutex;
use std::time::Duration;

/// A deterministic, frame-indexed chaos plan.  Shared across worker
/// threads via `Arc`; interior mutability makes each entry one-shot.
#[derive(Debug, Default)]
pub struct FaultScript {
    inner: Mutex<Plan>,
}

#[derive(Debug, Default)]
struct Plan {
    panic_at: HashMap<u64, String>,
    delay_at: HashMap<u64, Duration>,
    corrupt_at: HashMap<u64, f64>,
    panic_at_dequeue: HashMap<u64, String>,
}

impl FaultScript {
    pub fn new() -> Self {
        Self::default()
    }

    /// Panic (with `reason`) inside the worker evaluating frame `seq`.
    pub fn panic_at(mut self, seq: u64, reason: &str) -> Self {
        self.inner.get_mut().unwrap().panic_at.insert(seq, reason.to_string());
        self
    }

    /// Sleep `delay` inside the worker evaluating frame `seq`.
    pub fn delay_at(mut self, seq: u64, delay: Duration) -> Self {
        self.inner.get_mut().unwrap().delay_at.insert(seq, delay);
        self
    }

    /// Corrupt frame `seq`'s first pixel to `value` (NaN/Inf) before the
    /// session validates it.
    pub fn corrupt_at(mut self, seq: u64, value: f64) -> Self {
        self.inner.get_mut().unwrap().corrupt_at.insert(seq, value);
        self
    }

    /// Panic (with `reason`) inside the worker *dequeuing* frame `seq` —
    /// while the job-queue mutex is held and before the job is claimed.
    /// Exercises the pool's poisoned-lock recovery: the mutex is poisoned
    /// by the unwind, the frame stays queued for a healthy peer, and the
    /// dead worker is respawned.
    pub fn panic_at_dequeue(mut self, seq: u64, reason: &str) -> Self {
        self.inner.get_mut().unwrap().panic_at_dequeue.insert(seq, reason.to_string());
        self
    }

    // --- hook sites (called by the session runtime) -----------------------

    /// Worker-side hook: fire the (one-shot) panic and/or delay armed for
    /// `seq`.  Called inside the worker's `catch_unwind` boundary.
    pub fn fire(&self, seq: u64) {
        // take both under one short lock; sleep and panic outside it so a
        // poisoned/contended mutex never outlives the hook
        let (panic_reason, delay) = {
            let mut plan = self.inner.lock().unwrap();
            (plan.panic_at.remove(&seq), plan.delay_at.remove(&seq))
        };
        if let Some(d) = delay {
            std::thread::sleep(d);
        }
        if let Some(reason) = panic_reason {
            panic!("injected fault at frame {seq}: {reason}");
        }
    }

    /// Submission-side hook: the (one-shot) corruption value armed for
    /// `seq`, if any.
    pub fn corruption(&self, seq: u64) -> Option<f64> {
        self.inner.lock().unwrap().corrupt_at.remove(&seq)
    }

    /// Dequeue-side hook: fire the (one-shot) mid-dequeue panic armed for
    /// `seq`.  Called by the pool's job queue with its own lock held, so
    /// the unwind poisons the queue mutex on purpose.
    pub fn fire_dequeue(&self, seq: u64) {
        let armed = self.inner.lock().unwrap().panic_at_dequeue.remove(&seq);
        if let Some(reason) = armed {
            panic!("injected dequeue fault at frame {seq}: {reason}");
        }
    }

    /// Number of armed (not yet fired) faults — lets tests assert every
    /// injected fault actually struck.
    pub fn armed(&self) -> usize {
        let plan = self.inner.lock().unwrap();
        plan.panic_at.len()
            + plan.delay_at.len()
            + plan.corrupt_at.len()
            + plan.panic_at_dequeue.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hooks_are_one_shot() {
        let script = FaultScript::new()
            .delay_at(3, Duration::from_millis(1))
            .corrupt_at(5, f64::NAN);
        assert_eq!(script.armed(), 2);
        script.fire(0); // nothing armed for 0
        assert_eq!(script.armed(), 2);
        script.fire(3); // sleeps 1ms, disarms
        assert_eq!(script.armed(), 1);
        script.fire(3); // disarmed: no-op
        assert!(script.corruption(5).unwrap().is_nan());
        assert_eq!(script.corruption(5), None);
        assert_eq!(script.armed(), 0);
    }

    #[test]
    fn panic_hook_fires_with_the_reason() {
        let script = FaultScript::new().panic_at(7, "chaos");
        let err = std::panic::catch_unwind(|| script.fire(7)).unwrap_err();
        let msg = err.downcast_ref::<String>().unwrap();
        assert!(msg.contains("frame 7"), "{msg}");
        assert!(msg.contains("chaos"), "{msg}");
        // one-shot: the respawned worker does not re-trip it
        script.fire(7);
    }
}
