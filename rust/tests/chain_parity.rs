//! Chain-parity harness: a fused multi-stage plan (stage i+1's window
//! generator fed row by row from stage i's output, no intermediate
//! frames) must be **bit-identical** to sequentially applying each filter
//! to full materialised frames, for every stage combination, under every
//! [`ExecPlan`], in both numeric modes, including ragged widths that
//! exercise the lane replication of the batched window traversal.
//!
//! The stage pool mixes built-in netlists with DSL-compiled programs
//! (`nlfilter.dsl`, `sobel.dsl`) — the `Pipeline` builder treats both
//! uniformly.  All execution runs through
//! `Pipeline` → `CompiledPipeline` → `Session`; the independent
//! reference materialises a frame after every stage through fresh
//! single-stage plans.

use fpspatial::filters::{FilterKind, HwFilter};
use fpspatial::fpcore::{FloatFormat, OpMode};
use fpspatial::pipeline::{CompiledPipeline, ExecPlan, Pipeline};
use fpspatial::video::Frame;

const F16: FloatFormat = FloatFormat::new(10, 5);

const NLFILTER_DSL: &str = include_str!("../../examples/dsl/nlfilter.dsl");
const SOBEL_DSL: &str = include_str!("../../examples/dsl/sobel.dsl");
const FIG12_DSL: &str = include_str!("../../examples/dsl/fig12.dsl");

/// The stage pool: three built-ins + two DSL-compiled programs.
#[derive(Debug, Clone, Copy, PartialEq)]
enum Stage {
    Builtin(FilterKind),
    Dsl(&'static str, &'static str),
}

const STAGES: [Stage; 5] = [
    Stage::Builtin(FilterKind::Conv3x3),
    Stage::Builtin(FilterKind::Median),
    Stage::Builtin(FilterKind::FpSobel),
    Stage::Dsl("nlfilter_dsl", NLFILTER_DSL),
    Stage::Dsl("sobel_dsl", SOBEL_DSL),
];

/// The four execution plans every chain must agree across.
const EXECS: [ExecPlan; 4] = [
    ExecPlan::Scalar,
    ExecPlan::Batched,
    ExecPlan::Tiled { workers: 3 },
    ExecPlan::Streaming { workers: 3, reorder: 4 },
];

fn add_stage(p: Pipeline, stage: Stage) -> Pipeline {
    match stage {
        Stage::Builtin(kind) => p.builtin(kind),
        Stage::Dsl(name, src) => p.dsl_named(src, name),
    }
}

fn chain_plan(stages: &[Stage], mode: OpMode) -> CompiledPipeline {
    let mut p = Pipeline::new();
    for &s in stages {
        p = add_stage(p, s);
    }
    p.compile(mode).unwrap()
}

/// Independent reference: materialise a full frame after every stage,
/// through freshly compiled *single-stage* plans (no chain fusion, no
/// shared sessions).
fn sequential_reference(stages: &[Stage], frame: &Frame, mode: OpMode) -> Frame {
    let mut cur = frame.clone();
    for &s in stages {
        cur = chain_plan(&[s], mode).run_frame_sequential(&cur);
    }
    cur
}

/// Bitwise frame comparison (catches even 0.0 vs -0.0 divergence).
fn assert_bit_identical(a: &Frame, b: &Frame, what: &str) {
    assert_eq!((a.width, a.height), (b.width, b.height), "{what}: shape");
    for (i, (x, y)) in a.data.iter().zip(&b.data).enumerate() {
        assert_eq!(
            x.to_bits(),
            y.to_bits(),
            "{what}: pixel {i} ({}, {}) differs: {x} vs {y}",
            i % a.width,
            i / a.width
        );
    }
}

fn stage_label(stages: &[Stage]) -> String {
    let names: Vec<String> = stages
        .iter()
        .map(|s| match s {
            Stage::Builtin(k) => k.name().to_string(),
            Stage::Dsl(n, _) => n.to_string(),
        })
        .collect();
    names.join("->")
}

/// Run one chain through one execution plan and compare to the reference.
fn check_chain(stages: &[Stage], frame: &Frame, mode: OpMode, exec: ExecPlan) {
    let want = sequential_reference(stages, frame, mode);
    let plan = chain_plan(stages, mode);
    let label = format!("{} {mode:?} {exec}", stage_label(stages));
    let got = plan.session(exec).unwrap().process(frame).unwrap();
    assert_bit_identical(&got, &want, &label);
}

/// Every ordered 2-stage combination, full plan × mode matrix, on a
/// ragged-width frame (37 = 2·LANES + 5).
#[test]
fn two_stage_chains_bit_identical_all_plans_both_modes() {
    let frame = Frame::test_card(37, 17);
    for &a in &STAGES {
        for &b in &STAGES {
            let stages = [a, b];
            for mode in [OpMode::Exact, OpMode::Poly] {
                for exec in EXECS {
                    check_chain(&stages, &frame, mode, exec);
                }
            }
        }
    }
}

/// Every ordered 3-stage combination.  The plan × mode configuration
/// rotates deterministically with the combination index so the whole
/// matrix is covered across the suite without repeating all 8 configs on
/// all 125 chains.
#[test]
fn three_stage_chains_bit_identical() {
    let frame = Frame::salt_pepper(21, 11, 0.12, 9); // 21 = LANES + 5: ragged
    let mut idx = 0usize;
    for &a in &STAGES {
        for &b in &STAGES {
            for &c in &STAGES {
                let stages = [a, b, c];
                let mode = if (idx / EXECS.len()) % 2 == 0 { OpMode::Exact } else { OpMode::Poly };
                check_chain(&stages, &frame, mode, EXECS[idx % EXECS.len()]);
                idx += 1;
            }
        }
    }
}

/// Ragged and narrow widths (below one lane, one lane exactly, multiple
/// + 1, 2·lanes + 5) through the batched and tiled fused plans.
#[test]
fn ragged_widths_exercise_lane_replication() {
    let stages = [Stage::Builtin(FilterKind::Median), Stage::Dsl("sobel_dsl", SOBEL_DSL)];
    for w in [7usize, 16, 33, 37] {
        let frame = Frame::noise(w, 9, w as u64);
        let want = sequential_reference(&stages, &frame, OpMode::Exact);
        let plan = chain_plan(&stages, OpMode::Exact);
        assert_bit_identical(
            &plan.session(ExecPlan::Batched).unwrap().process(&frame).unwrap(),
            &want,
            &format!("batched w={w}"),
        );
        assert_bit_identical(
            &plan.session(ExecPlan::Tiled { workers: 4 }).unwrap().process(&frame).unwrap(),
            &want,
            &format!("tiled w={w}"),
        );
    }
}

/// A 5x5 stage has a two-row halo; stacking it twice around a 3x3 stage
/// exercises the accumulated inter-stage halo arithmetic of tiled chains.
#[test]
fn wide_window_stages_accumulate_tile_halos() {
    let stages = [
        Stage::Builtin(FilterKind::Conv5x5),
        Stage::Builtin(FilterKind::Median),
        Stage::Builtin(FilterKind::Conv5x5),
    ];
    let frame = Frame::test_card(37, 19);
    let want = sequential_reference(&stages, &frame, OpMode::Exact);
    let plan = chain_plan(&stages, OpMode::Exact);
    assert_eq!(plan.total_halo(), 2 + 1 + 2);
    for workers in [1usize, 2, 5, 19, 64] {
        let got = plan.session(ExecPlan::Tiled { workers }).unwrap().process(&frame).unwrap();
        assert_bit_identical(&got, &want, &format!("workers={workers}"));
    }
}

/// Frames shorter than the accumulated halo (h=3 with P=4) still match —
/// the fused crop covers the whole frame and border replication takes
/// over.
#[test]
fn short_frames_shorter_than_the_total_halo() {
    let stages = [
        Stage::Builtin(FilterKind::Conv5x5),
        Stage::Builtin(FilterKind::Conv5x5),
    ];
    for h in [1usize, 2, 3, 5] {
        let frame = Frame::noise(23, h, h as u64 + 77);
        let want = sequential_reference(&stages, &frame, OpMode::Exact);
        let plan = chain_plan(&stages, OpMode::Exact);
        for exec in [ExecPlan::Scalar, ExecPlan::Batched, ExecPlan::Tiled { workers: 3 }] {
            assert_bit_identical(
                &plan.session(exec).unwrap().process(&frame).unwrap(),
                &want,
                &format!("{exec} h={h}"),
            );
        }
    }
}

/// Chains stream through a long-lived multi-worker session in order and
/// bit-identical.
#[test]
fn chain_through_streaming_session() {
    let stages = [
        Stage::Builtin(FilterKind::Median),
        Stage::Dsl("nlfilter_dsl", NLFILTER_DSL),
        Stage::Builtin(FilterKind::FpSobel),
    ];
    let plan = chain_plan(&stages, OpMode::Exact);
    let frames = fpspatial::coordinator::synth_sequence(33, 14, 6);
    let mut session = plan.session(ExecPlan::streaming(3)).unwrap();
    let mut outs = Vec::new();
    let m = session.process_sequence(frames.clone(), |_, f| outs.push(f)).unwrap();
    assert_eq!(m.frames, 6);
    for (i, (f, got)) in frames.iter().zip(&outs).enumerate() {
        let want = sequential_reference(&stages, f, OpMode::Exact);
        assert_bit_identical(got, &want, &format!("pipeline frame {i}"));
    }
}

/// A single-stage chain is exactly the plain filter.
#[test]
fn single_stage_chain_is_the_plain_filter() {
    for &s in &STAGES {
        let frame = Frame::test_card(24, 13);
        for mode in [OpMode::Exact, OpMode::Poly] {
            let plan = chain_plan(&[s], mode);
            let want = plan.run_frame_sequential(&frame);
            for exec in EXECS {
                assert_bit_identical(
                    &plan.session(exec).unwrap().process(&frame).unwrap(),
                    &want,
                    &format!("{} {mode:?} {exec}", stage_label(&[s])),
                );
            }
        }
    }
}

// ---------------------------------------------------------------------
// Mixed-precision chains: stages with differing (m, e).  The plan
// inserts an explicit converter at every boundary where formats differ
// (quantize into the consumer's format); the independent reference below
// applies the same re-rounding to a fully materialised frame by hand —
// per-stage *quantized* application.
// ---------------------------------------------------------------------

fn add_stage_fmt(p: Pipeline, stage: Stage, fmt: FloatFormat) -> Pipeline {
    add_stage(p, stage).format(fmt)
}

fn mixed_chain_plan(stages: &[(Stage, FloatFormat)], mode: OpMode) -> CompiledPipeline {
    let mut p = Pipeline::new();
    for &(s, f) in stages {
        p = add_stage_fmt(p, s, f);
    }
    p.compile(mode).unwrap()
}

/// Independent mixed-precision reference: materialise after every stage
/// and quantize the frame into the next stage's format where it differs,
/// using freshly compiled single-stage plans and a direct `quantize`
/// call (not the plan's own converter code).
fn sequential_reference_mixed(
    stages: &[(Stage, FloatFormat)],
    frame: &Frame,
    mode: OpMode,
) -> Frame {
    let mut cur = frame.clone();
    let mut prev: Option<FloatFormat> = None;
    for &(s, fmt) in stages {
        if prev.is_some_and(|p| p != fmt) {
            for v in &mut cur.data {
                *v = fpspatial::fpcore::quantize(*v, fmt);
            }
        }
        cur = mixed_chain_plan(&[(s, fmt)], mode).run_frame_sequential(&cur);
        prev = Some(fmt);
    }
    cur
}

fn check_mixed_chain(
    stages: &[(Stage, FloatFormat)],
    frame: &Frame,
    mode: OpMode,
    exec: ExecPlan,
) {
    let want = sequential_reference_mixed(stages, frame, mode);
    let plan = mixed_chain_plan(stages, mode);
    let names: Vec<String> =
        stages.iter().map(|&(s, f)| format!("{}@{}", stage_label(&[s]), f.name())).collect();
    let label = format!("{} {mode:?} {exec}", names.join("->"));
    let got = plan.session(exec).unwrap().process(frame).unwrap();
    assert_bit_identical(&got, &want, &label);
}

const F24: FloatFormat = FloatFormat::new(16, 7);
const F32F: FloatFormat = FloatFormat::new(23, 8);
const F14: FloatFormat = FloatFormat::new(7, 6);

/// Two-stage mixed-format chains (widening, narrowing, DSL stages) are
/// bit-identical to sequential per-stage quantized application through
/// every execution plan in both numeric modes.
#[test]
fn mixed_format_two_stage_chains_all_plans_both_modes() {
    let frame = Frame::test_card(37, 15); // ragged width: 2·LANES + 5
    let combos: [[(Stage, FloatFormat); 2]; 4] = [
        // wide denoiser -> narrow edge detector (the paper's use case)
        [(Stage::Builtin(FilterKind::Median), F24), (Stage::Builtin(FilterKind::FpSobel), F16)],
        // narrowing into a tiny format exercises saturation + flush
        [(Stage::Builtin(FilterKind::Conv3x3), F32F), (Stage::Builtin(FilterKind::Median), F14)],
        // widening boundary (lossless — converter still explicit)
        [(Stage::Builtin(FilterKind::Median), F16), (Stage::Builtin(FilterKind::Conv3x3), F32F)],
        // DSL stages take per-stage formats too
        [
            (Stage::Dsl("nlfilter_dsl", NLFILTER_DSL), F16),
            (Stage::Dsl("sobel_dsl", SOBEL_DSL), F24),
        ],
    ];
    for stages in &combos {
        for mode in [OpMode::Exact, OpMode::Poly] {
            for exec in EXECS {
                check_mixed_chain(stages, &frame, mode, exec);
            }
        }
    }
}

/// A three-stage wide→narrow→wide chain with a 5x5 stage: accumulated
/// tile halos and two active converters at once.
#[test]
fn mixed_format_three_stage_chain_with_accumulated_halos() {
    let stages = [
        (Stage::Builtin(FilterKind::Conv5x5), F32F),
        (Stage::Builtin(FilterKind::Median), F14),
        (Stage::Builtin(FilterKind::FpSobel), F24),
    ];
    let frame = Frame::salt_pepper(29, 13, 0.12, 3);
    for mode in [OpMode::Exact, OpMode::Poly] {
        for exec in EXECS {
            check_mixed_chain(&stages, &frame, mode, exec);
        }
    }
}

/// Saturating boundary: a stage format whose max value is far below the
/// 0..255 pixel range — fused and sequential must clamp identically.
#[test]
fn mixed_format_saturating_boundary() {
    // float10(6,3): max = (2 − 2⁻⁶)·2⁴ = 31.75 « 255
    let tiny = FloatFormat::new(6, 3);
    let stages = [
        (Stage::Builtin(FilterKind::Conv3x3), F24),
        (Stage::Builtin(FilterKind::Median), tiny),
    ];
    let frame = Frame::test_card(23, 11);
    for exec in [ExecPlan::Scalar, ExecPlan::Batched, ExecPlan::Tiled { workers: 3 }] {
        check_mixed_chain(&stages, &frame, OpMode::Exact, exec);
    }
    // and the chain's output really lives on the tiny grid
    let plan = mixed_chain_plan(&stages, OpMode::Exact);
    let out = plan.session(ExecPlan::Scalar).unwrap().process(&frame).unwrap();
    for &v in &out.data {
        assert!(v.abs() <= tiny.max_value(), "{v} exceeds {}", tiny.max_value());
        assert_eq!(fpspatial::fpcore::quantize(v, tiny).to_bits(), v.to_bits());
    }
}

/// Mixed-format chains stream through a long-lived multi-worker session
/// bit-identically too.
#[test]
fn mixed_format_chain_through_streaming_session() {
    let stages = [
        (Stage::Builtin(FilterKind::Median), F24),
        (Stage::Dsl("sobel_dsl", SOBEL_DSL), F16),
    ];
    let plan = mixed_chain_plan(&stages, OpMode::Exact);
    let frames = fpspatial::coordinator::synth_sequence(33, 14, 5);
    let mut session = plan.session(ExecPlan::streaming(3)).unwrap();
    let mut outs = Vec::new();
    let m = session.process_sequence(frames.clone(), |_, f| outs.push(f)).unwrap();
    assert_eq!(m.frames, 5);
    for (i, (f, got)) in frames.iter().zip(&outs).enumerate() {
        let want = sequential_reference_mixed(&stages, f, OpMode::Exact);
        assert_bit_identical(got, &want, &format!("mixed pipeline frame {i}"));
    }
}

/// The plan reports its converters: formats, boundary positions, and
/// the added cascade latency.
#[test]
fn mixed_format_chain_reports_converters() {
    use fpspatial::fpcore::FmtConvert;
    let plan = mixed_chain_plan(
        &[
            (Stage::Builtin(FilterKind::Median), F24),
            (Stage::Builtin(FilterKind::FpSobel), F16),
            (Stage::Builtin(FilterKind::Conv3x3), F16),
        ],
        OpMode::Exact,
    );
    assert!(plan.is_mixed_format());
    assert_eq!(plan.converters(), vec![Some(FmtConvert::new(F24, F16)), None]);
    // stage latencies + one 2-cycle converter
    assert_eq!(plan.datapath_latency(), 19 + 39 + 26 + 2);
    // uniform chain: no converters, no extra cycles
    let uniform = chain_plan(
        &[Stage::Builtin(FilterKind::Median), Stage::Builtin(FilterKind::FpSobel)],
        OpMode::Exact,
    );
    assert!(!uniform.is_mixed_format());
    assert_eq!(uniform.datapath_latency(), 19 + 39);
}

/// Scalar DSL programs (fig. 12 has no sliding_window) are rejected as
/// chain stages with a usable error, not a panic.
#[test]
fn scalar_dsl_program_rejected_as_chain_stage() {
    let err = Pipeline::new()
        .dsl_named(FIG12_DSL, "fig12")
        .compile(OpMode::Exact)
        .unwrap_err();
    assert!(format!("{err:#}").contains("sliding_window"), "{err:#}");
}

// ---------------------------------------------------------------------
// Strided chains: stages whose output frame is *smaller* than their
// input (stride ≥ 2, pooling).  The fused runner re-plans its band
// crops per stage; the reference below materialises the shrunken frame
// after every stage through fresh single-stage plans (re-rounding at
// mixed-format boundaries exactly like the chain's converters).
// ---------------------------------------------------------------------

fn hw_chain_reference(stages: &[HwFilter], frame: &Frame, mode: OpMode) -> Frame {
    let mut cur = frame.clone();
    let mut prev: Option<FloatFormat> = None;
    for hw in stages {
        if prev.is_some_and(|p| p != hw.fmt) {
            for v in &mut cur.data {
                *v = fpspatial::fpcore::quantize(*v, hw.fmt);
            }
        }
        prev = Some(hw.fmt);
        cur = Pipeline::from_stages([hw.clone()])
            .compile(mode)
            .unwrap()
            .run_frame_sequential(&cur);
    }
    cur
}

/// A stride-2 conv feeding a full-rate median: the second stage windows
/// a frame half the size of the input, under every plan in both modes.
#[test]
fn stride2_chain_shrinks_between_stages_all_plans_both_modes() {
    let stages = [
        HwFilter::new(FilterKind::Conv3x3, F16).unwrap().with_stride(2),
        HwFilter::new(FilterKind::Median, F16).unwrap(),
    ];
    let frame = Frame::test_card(37, 17); // ragged: 37→19 between stages
    for mode in [OpMode::Exact, OpMode::Poly] {
        let plan = Pipeline::from_stages(stages.clone()).compile(mode).unwrap();
        assert_eq!(plan.output_dims(37, 17), (19, 9));
        let want = hw_chain_reference(&stages, &frame, mode);
        assert_eq!((want.width, want.height), (19, 9));
        for exec in EXECS {
            let got = plan.session(exec).unwrap().process(&frame).unwrap();
            assert_bit_identical(&got, &want, &format!("conv3x3/s2->median {mode:?} {exec}"));
        }
    }
}

/// Two stride-2 reductions stacked (conv/s2 then 2×2 pool) quarter the
/// frame; tiled halo planning must follow the shrinking geometry for
/// every worker count.
#[test]
fn stacked_stride2_stages_quarter_the_frame() {
    let stages = [
        HwFilter::new(FilterKind::Conv3x3, F16).unwrap().with_stride(2),
        HwFilter::max_pool(F16, 2, 2).unwrap(),
    ];
    let frame = Frame::noise(29, 15, 5); // 29→15→8 wide, 15→8→4 tall
    let plan = Pipeline::from_stages(stages.clone()).compile(OpMode::Exact).unwrap();
    assert_eq!(plan.output_dims(29, 15), (8, 4));
    let want = hw_chain_reference(&stages, &frame, OpMode::Exact);
    assert_eq!((want.width, want.height), (8, 4));
    for exec in EXECS {
        let got = plan.session(exec).unwrap().process(&frame).unwrap();
        assert_bit_identical(&got, &want, &format!("conv/s2->pool2 {exec}"));
    }
    for workers in [1usize, 2, 4, 16] {
        let got =
            plan.session(ExecPlan::Tiled { workers }).unwrap().process(&frame).unwrap();
        assert_bit_identical(&got, &want, &format!("conv/s2->pool2 tiled:{workers}"));
    }
}

/// A VGG-style conv→relu→conv→relu→pool block with per-layer formats:
/// the CNN shape the descriptor files describe, checked stage-by-stage
/// against materialised frames.
#[test]
fn vgg_style_conv_relu_pool_chain_all_plans_both_modes() {
    let stages = [
        HwFilter::new(FilterKind::Conv3x3, F24).unwrap(),
        HwFilter::relu(F24),
        HwFilter::new(FilterKind::Conv3x3, F16).unwrap(),
        HwFilter::relu(F16),
        HwFilter::max_pool(F16, 2, 2).unwrap(),
    ];
    let frame = Frame::test_card(33, 21); // ragged: LANES·2 + 1
    for mode in [OpMode::Exact, OpMode::Poly] {
        let plan = Pipeline::from_stages(stages.clone()).compile(mode).unwrap();
        assert_eq!(plan.output_dims(33, 21), (17, 11));
        assert!(plan.is_mixed_format());
        let want = hw_chain_reference(&stages, &frame, mode);
        assert_eq!((want.width, want.height), (17, 11));
        for exec in EXECS {
            let got = plan.session(exec).unwrap().process(&frame).unwrap();
            assert_bit_identical(&got, &want, &format!("vgg block {mode:?} {exec}"));
        }
    }
}

/// The fused chain reports the combined O(N·ksize) line-buffer footprint,
/// not N-1 intermediate frames.
#[test]
fn chain_reports_combined_line_buffers() {
    let plan = chain_plan(
        &[Stage::Builtin(FilterKind::Conv5x5), Stage::Builtin(FilterKind::Median)],
        OpMode::Exact,
    );
    // conv5x5: 4 line buffers, median: 2 — at 16 bits each
    assert_eq!(plan.line_buffer_bits(1920), (4 + 2) * 1920 * 16);
    assert_eq!(plan.datapath_latency(), 32 + 19);
}
