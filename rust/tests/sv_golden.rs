//! Golden-file snapshots of the SystemVerilog emitter: every program in
//! `examples/dsl/` plus a two-stage mixed-format cascade, compared
//! byte-for-byte against checked-in goldens under `tests/goldens/sv/`.
//!
//! * A **missing** golden is bootstrapped (written and the test passes
//!   with a note) so a fresh checkout stays green; CI regenerates the
//!   goldens on every run and `git diff`s the checked-in ones, so any
//!   emitter drift fails the build once the files are committed.
//! * Regenerate intentionally with `UPDATE_SV_GOLDENS=1 cargo test
//!   --test sv_golden` and commit the diff.
//!
//! Structural assertions below run on the freshly generated text too, so
//! the test is meaningful even on a bootstrap run.

use std::path::{Path, PathBuf};

use fpspatial::dsl;
use fpspatial::filters::FilterKind;
use fpspatial::fpcore::OpMode;
use fpspatial::pipeline::Pipeline;

fn dsl_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("../examples/dsl")
}

fn goldens_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/goldens/sv")
}

/// Compare `generated` against the checked-in golden `name.sv`,
/// bootstrapping the file if it does not exist yet.
fn check_golden(name: &str, generated: &str) {
    let dir = goldens_dir();
    let path = dir.join(format!("{name}.sv"));
    let update = std::env::var("UPDATE_SV_GOLDENS").is_ok();
    if update || !path.exists() {
        std::fs::create_dir_all(&dir).expect("create goldens dir");
        std::fs::write(&path, generated).expect("write golden");
        if !update {
            eprintln!("bootstrapped golden {} — commit it to lock the snapshot", path.display());
        }
        return;
    }
    let want = std::fs::read_to_string(&path).expect("read golden");
    assert!(
        generated == want,
        "{name}: emitted SystemVerilog drifted from tests/goldens/sv/{name}.sv \
         (regenerate intentionally with UPDATE_SV_GOLDENS=1 cargo test --test sv_golden \
         and commit the diff)"
    );
}

/// Every committed DSL example emits stable SystemVerilog.
#[test]
fn every_dsl_example_matches_its_golden() {
    let mut programs: Vec<PathBuf> = std::fs::read_dir(dsl_dir())
        .expect("examples/dsl exists")
        .filter_map(|e| {
            let p = e.ok()?.path();
            (p.extension().and_then(|x| x.to_str()) == Some("dsl")).then_some(p)
        })
        .collect();
    programs.sort();
    assert!(programs.len() >= 6, "expected the committed DSL suite, got {programs:?}");
    for p in programs {
        let stem = p.file_stem().unwrap().to_str().unwrap().to_string();
        let src = std::fs::read_to_string(&p).unwrap();
        let compiled = dsl::compile(&src, &stem).unwrap_or_else(|e| panic!("{stem}: {e:#}"));
        let sv = dsl::sverilog::generate(&compiled);
        // structural sanity independent of the snapshot
        assert!(sv.contains(&format!("module {stem} #(")), "{stem}");
        assert_eq!(sv.matches("endmodule").count(), 1, "{stem}");
        check_golden(&stem, &sv);
    }
}

/// The emitter is deterministic: two generations are byte-identical
/// (goldens would be meaningless otherwise).
#[test]
fn emitter_is_deterministic() {
    let src = std::fs::read_to_string(dsl_dir().join("nlfilter.dsl")).unwrap();
    let a = dsl::sverilog::generate(&dsl::compile(&src, "nl").unwrap());
    let b = dsl::sverilog::generate(&dsl::compile(&src, "nl").unwrap());
    assert_eq!(a, b);
}

/// A two-stage mixed-format cascade — the walk-through chain
/// `median(10,5) → fp_sobel(7,6)` — emits ONE top module instantiating
/// both stages plus the boundary converter, snapshot-locked.  Built and
/// emitted through the `Pipeline` → `CompiledPipeline` plan API.
#[test]
fn mixed_format_cascade_matches_its_golden() {
    let plan = Pipeline::new()
        .builtin(FilterKind::Median)
        .fmt(10, 5)
        .builtin(FilterKind::FpSobel)
        .fmt(7, 6)
        .compile(OpMode::Exact)
        .unwrap();
    let sv = plan.emit_sv("median_sobel_cascade", (1920, 1080));
    // structural sanity independent of the snapshot: 2 stage modules +
    // 1 top, one fmt_converter instance, per-stage window widths
    assert_eq!(sv.matches("endmodule").count(), 3);
    assert!(sv.contains("module median_sobel_cascade #("));
    assert!(sv.contains("module median_sobel_cascade_s0_median #("));
    assert!(sv.contains("module median_sobel_cascade_s1_fp_sobel #("));
    assert_eq!(sv.matches("fmt_converter #(").count(), 1);
    assert!(sv.contains(".SRC_MANTISSA(10), .SRC_EXP(5), .SRC_BIAS(15),"));
    assert!(sv.contains(".DST_MANTISSA(7), .DST_EXP(6), .DST_BIAS(31)"));
    assert_eq!(sv.matches("generateWindow #(").count(), 2);
    check_golden("median_sobel_cascade", &sv);
}
