//! Frame-server contract (`pipeline::FrameServer`): N independent
//! streams over ONE shared worker pool, each keeping the full
//! single-session guarantees.
//!
//! * every stream's outputs are delivered strictly in submission order
//!   and **bit-identical** to a solo [`Session`] under every
//!   [`ExecPlan`] (and to the sequential oracle) — multiplexing changes
//!   scheduling, never pixels;
//! * per-stream [`Metrics`] on a healthy run are exactly what the same
//!   stream reports running alone (all fault counters zero, delivered
//!   == submitted), and the aggregate equals the per-stream sum;
//! * geometry pinning, input validation and builder errors are
//!   per-stream and typed.
//!
//! [`Session`]: fpspatial::pipeline::Session

use std::thread;

use fpspatial::filters::FilterKind;
use fpspatial::fpcore::OpMode;
use fpspatial::pipeline::{
    CompiledPipeline, ExecError, ExecPlan, FrameServer, Pipeline, ServerEvent, SessionConfig,
    Submitted,
};
use fpspatial::video::Frame;

const EXECS: [ExecPlan; 4] = [
    ExecPlan::Scalar,
    ExecPlan::Batched,
    ExecPlan::Tiled { workers: 2 },
    ExecPlan::Streaming { workers: 2, reorder: 2 },
];

fn builtin(kind: FilterKind) -> CompiledPipeline {
    Pipeline::new().builtin(kind).compile(OpMode::Exact).unwrap()
}

fn assert_bit_identical(a: &Frame, b: &Frame, what: &str) {
    assert_eq!((a.width, a.height), (b.width, b.height), "{what}: shape");
    for (i, (x, y)) in a.data.iter().zip(&b.data).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: pixel {i}: {x} vs {y}");
    }
}

/// Partition a drained event list into per-stream (seq, frame) runs,
/// panicking on any fault.
fn by_stream(events: Vec<ServerEvent>, streams: usize) -> Vec<Vec<(u64, Frame)>> {
    let mut got: Vec<Vec<(u64, Frame)>> = vec![Vec::new(); streams];
    for ev in events {
        match ev {
            ServerEvent::Frame { stream, seq, frame, .. } => got[stream].push((seq, frame)),
            ServerEvent::Fault { stream, error } => {
                panic!("unexpected fault on stream {stream}: {error}")
            }
        }
    }
    got
}

/// The headline contract: three streams with *different* plans and
/// geometries share one pool, and each comes out in order and
/// bit-identical to a solo session under every execution plan.
#[test]
fn n_streams_are_bit_identical_to_solo_sessions_under_every_plan() {
    const F: usize = 5;
    let plans = [
        builtin(FilterKind::Median),
        builtin(FilterKind::Conv3x3),
        Pipeline::new()
            .builtin(FilterKind::Median)
            .builtin(FilterKind::FpSobel)
            .compile(OpMode::Exact)
            .unwrap(),
    ];
    let sizes = [(32, 24), (24, 16), (40, 20)];
    let inputs: Vec<Vec<Frame>> = sizes
        .iter()
        .enumerate()
        .map(|(s, &(w, h))| (0..F).map(|i| Frame::noise(w, h, (s * 100 + i) as u64)).collect())
        .collect();

    let mut server = FrameServer::builder(3)
        .stream(&plans[0], SessionConfig::new())
        .stream(&plans[1], SessionConfig::new())
        .stream(&plans[2], SessionConfig::new())
        .build()
        .unwrap();
    for i in 0..F {
        for s in 0..3 {
            let sub = server.submit(s, &inputs[s][i]).unwrap();
            assert_eq!(sub, Submitted::Queued(i as u64), "stream {s} frame {i}");
        }
    }
    let got = by_stream(server.drain().unwrap(), 3);

    for s in 0..3 {
        assert_eq!(got[s].len(), F, "stream {s} delivered every frame");
        for (i, (seq, frame)) in got[s].iter().enumerate() {
            assert_eq!(*seq, i as u64, "stream {s} delivers in submission order");
            let oracle = plans[s].run_frame_sequential(&inputs[s][i]);
            assert_bit_identical(frame, &oracle, &format!("stream {s} frame {i} vs oracle"));
        }
        for exec in EXECS {
            let mut solo = plans[s].session(exec).unwrap();
            for (i, (_, frame)) in got[s].iter().enumerate() {
                let want = solo.process(&inputs[s][i]).unwrap();
                assert_bit_identical(frame, &want, &format!("stream {s} frame {i} vs {exec}"));
            }
        }
    }
}

/// Healthy-run accounting: each stream's counters through the shared
/// pool are identical to the same stream running alone (delivered ==
/// submitted, zero faults), and the aggregate is the per-stream sum.
#[test]
fn per_stream_metrics_match_solo_runs_and_aggregate_is_their_sum() {
    const N: usize = 4;
    const F: usize = 6;
    let plan = builtin(FilterKind::Median);
    let inputs: Vec<Frame> = (0..F).map(|i| Frame::noise(32, 24, i as u64)).collect();

    let mut builder = FrameServer::builder(2);
    for _ in 0..N {
        builder = builder.stream(&plan, SessionConfig::new());
    }
    let mut server = builder.build().unwrap();
    for f in &inputs {
        for s in 0..N {
            server.submit(s, f).unwrap();
        }
    }
    let got = by_stream(server.drain().unwrap(), N);

    // solo baseline: the same frame run through its own session
    let mut solo = plan.session(ExecPlan::streaming(2)).unwrap();
    let solo_m = solo.process_sequence(inputs.clone(), |_, _| {}).unwrap();
    assert_eq!(solo_m.delivered, F as u64);
    assert_eq!((solo_m.dropped, solo_m.deadline_misses, solo_m.worker_restarts), (0, 0, 0));

    for s in 0..N {
        assert_eq!(got[s].len(), F);
        let m = server.metrics(s);
        assert_eq!(m.submitted(), F as u64, "stream {s}");
        assert_eq!(m.delivered, solo_m.delivered, "stream {s} delivered == running alone");
        assert_eq!(
            (m.dropped, m.deadline_misses, m.worker_restarts),
            (solo_m.dropped, solo_m.deadline_misses, solo_m.worker_restarts),
            "stream {s} fault counters == running alone"
        );
    }
    let a = server.aggregate();
    assert_eq!(a.submitted(), (N * F) as u64, "aggregate submissions are the sum");
    assert_eq!(a.delivered, (N * F) as u64, "aggregate deliveries are the sum");
    let sums = (0..N).fold((0u64, 0u64, 0u64), |acc, s| {
        let m = server.metrics(s);
        (acc.0 + m.dropped, acc.1 + m.deadline_misses, acc.2 + m.worker_restarts)
    });
    assert_eq!((a.dropped, a.deadline_misses, a.worker_restarts), sums);
}

/// Channel ingest: producer threads feed [`StreamSender`]s, `run`
/// schedules until they hang up — outputs still per-stream in-order and
/// oracle-identical.
///
/// [`StreamSender`]: fpspatial::pipeline::StreamSender
#[test]
fn channel_ingest_run_delivers_every_stream_in_order() {
    const N: usize = 2;
    const F: usize = 6;
    let plan = builtin(FilterKind::Conv3x3);
    let inputs: Vec<Vec<Frame>> = (0..N)
        .map(|s| (0..F).map(|i| Frame::noise(28, 20, (s * 50 + i) as u64)).collect())
        .collect();

    let mut server = FrameServer::builder(2)
        .stream(&plan, SessionConfig::new())
        .stream(&plan, SessionConfig::new())
        .build()
        .unwrap();
    let senders: Vec<_> = (0..N).map(|s| server.sender(s).unwrap()).collect();

    let mut got: Vec<Vec<(u64, Frame)>> = vec![Vec::new(); N];
    thread::scope(|scope| {
        for (s, sender) in senders.into_iter().enumerate() {
            let frames = inputs[s].clone();
            scope.spawn(move || {
                for f in frames {
                    assert!(sender.send(f), "server hung up early");
                }
            });
        }
        server.run(|ev| match ev {
            ServerEvent::Frame { stream, seq, frame, .. } => {
                got[stream].push((seq, frame));
                None
            }
            ServerEvent::Fault { stream, error } => {
                panic!("unexpected fault on stream {stream}: {error}")
            }
        })
    })
    .unwrap();

    for s in 0..N {
        assert_eq!(got[s].len(), F, "stream {s}");
        for (i, (seq, frame)) in got[s].iter().enumerate() {
            assert_eq!(*seq, i as u64, "stream {s} in order");
            let oracle = plan.run_frame_sequential(&inputs[s][i]);
            assert_bit_identical(frame, &oracle, &format!("stream {s} frame {i}"));
        }
        assert_eq!(server.metrics(s).delivered, F as u64);
    }
}

/// Geometry pinning is per-stream: a stream latches its first frame's
/// size and rejects others, without disturbing its queued work or any
/// other stream.
#[test]
fn geometry_pinning_is_per_stream() {
    let plan = builtin(FilterKind::Median);
    let mut server = FrameServer::builder(2)
        .stream(&plan, SessionConfig::new())
        .stream(&plan, SessionConfig::new())
        .build()
        .unwrap();

    server.submit(0, &Frame::noise(32, 24, 1)).unwrap();
    let err = server.submit(0, &Frame::noise(48, 32, 2)).unwrap_err();
    assert!(err.to_string().contains("pinned"), "{err}");
    // stream 1 pins independently — the size stream 0 just rejected
    server.submit(1, &Frame::noise(48, 32, 3)).unwrap();
    let got = by_stream(server.drain().unwrap(), 2);
    assert_eq!((got[0].len(), got[1].len()), (1, 1));
    assert_eq!((got[1][0].1.width, got[1][0].1.height), (48, 32));
}

/// Input validation is per-stream and typed: a non-finite frame comes
/// back as [`ExecError::PoisonFrame`] and the stream keeps serving.
#[test]
fn a_poison_frame_is_rejected_per_stream_and_the_stream_keeps_serving() {
    let plan = builtin(FilterKind::Median);
    let mut server = FrameServer::builder(1).stream(&plan, SessionConfig::new()).build().unwrap();

    let good = Frame::noise(24, 16, 7);
    server.submit(0, &good).unwrap();
    let mut bad = Frame::noise(24, 16, 8);
    bad.data[5] = f64::NAN;
    let err = server.submit(0, &bad).unwrap_err();
    match err.downcast_ref::<ExecError>() {
        Some(ExecError::PoisonFrame { frame_seq, index, .. }) => {
            assert_eq!((*frame_seq, *index), (1, 5));
        }
        other => panic!("expected PoisonFrame, got {other:?}"),
    }
    server.submit(0, &good).unwrap();
    let got = by_stream(server.drain().unwrap(), 1);
    assert_eq!(got[0].len(), 2, "both good frames delivered");
    let m = server.metrics(0);
    assert_eq!((m.submitted(), m.delivered), (2, 2));
    assert_eq!((m.dropped, m.deadline_misses, m.worker_restarts), (0, 0, 0));
}

/// Builder and addressing errors are typed and early.
#[test]
fn builder_and_addressing_errors_are_reported() {
    let plan = builtin(FilterKind::Median);
    let err = FrameServer::builder(0).stream(&plan, SessionConfig::new()).build().unwrap_err();
    assert!(err.to_string().contains("worker"), "{err}");
    let err = FrameServer::builder(2).build().unwrap_err();
    assert!(err.to_string().contains("stream"), "{err}");
    let err = FrameServer::builder(2)
        .stream_with_queue(&plan, SessionConfig::new(), 0)
        .build()
        .unwrap_err();
    assert!(err.to_string().contains("budget"), "{err}");

    let mut server = FrameServer::builder(1).stream(&plan, SessionConfig::new()).build().unwrap();
    let err = server.submit(5, &Frame::noise(24, 16, 0)).unwrap_err();
    assert!(err.to_string().contains("unknown stream"), "{err}");
    assert!(server.sender(5).is_err());
}

/// ROADMAP open item 2 regression: the `run` scheduler is event-driven,
/// not a 1 ms poll.  Producers leave the server idle twice (50 ms gaps)
/// mid-run — the old spin loop would rack up ~dozens of progress-free
/// wakeups across those gaps; the blocking loop must report **zero**.
#[test]
fn idle_run_makes_no_progress_free_wakeups() {
    const F: usize = 3;
    let plan = builtin(FilterKind::Conv3x3);
    let mut server = FrameServer::builder(2)
        .stream(&plan, SessionConfig::new())
        .build()
        .unwrap();
    let sender = server.sender(0).unwrap();

    let mut delivered = 0usize;
    thread::scope(|scope| {
        scope.spawn(move || {
            for burst in 0..2 {
                for i in 0..F {
                    assert!(sender.send(Frame::noise(28, 20, (burst * 10 + i) as u64)));
                }
                // the server fully drains and then sits idle here
                thread::sleep(std::time::Duration::from_millis(50));
            }
        });
        server.run(|ev| match ev {
            ServerEvent::Frame { frame, .. } => {
                delivered += 1;
                Some(frame)
            }
            ServerEvent::Fault { stream, error } => {
                panic!("unexpected fault on stream {stream}: {error}")
            }
        })
    })
    .unwrap();

    assert_eq!(delivered, 2 * F, "every frame from both bursts delivered");
    assert_eq!(
        server.idle_wakeups(),
        0,
        "an idle event-driven server must never wake without progress"
    );
}

/// A DSL window program no other test compiles, so this binary's
/// kernel-cache deltas for it are interference-free.
const CACHE_PROBE_DSL: &str = "
use float(10, 5);
var float w[3][3], K[3][3], pix_i, pix_o;
image_resolution(1920, 1080);
w = sliding_window(pix_i, 3, 3);
K = [[0.4375, 0.125, 0.0625],
     [0.125, 0.21875, 0.125],
     [0.0625, 0.125, 0.4375]];
pix_o = conv3x3(w, K);
";

/// Tentpole cache contract: 64 server streams of one plan share ONE
/// compiled kernel — the only compile happens when the plan itself is
/// compiled; building the server, spawning the workers and running all
/// 64 streams adds zero cache misses (`KernelCache::stats()` deltas).
#[test]
fn sixty_four_streams_of_one_plan_compile_the_kernel_once() {
    use std::sync::Arc;

    use fpspatial::sim::KernelCache;
    const N: usize = 64;

    // the one (and only) compile for this netlist happens here
    let plan = Pipeline::new().dsl(CACHE_PROBE_DSL).compile(OpMode::Exact).unwrap();
    let cache = KernelCache::global();
    // exactly-once proof for THIS key: the kernel the plan compile
    // installed is the very Arc every later lookup returns
    let k_before = cache.get_or_compile(&plan.stages()[0].netlist, OpMode::Exact);
    let before = cache.stats();

    let mut builder = FrameServer::builder(4);
    for _ in 0..N {
        builder = builder.stream(&plan, SessionConfig::new());
    }
    let mut server = builder.build().unwrap();
    let input = Frame::noise(24, 16, 0xCACE);
    for s in 0..N {
        server.submit(s, &input).unwrap();
    }
    let got = by_stream(server.drain().unwrap(), N);

    let oracle = plan.run_frame_sequential(&input);
    for (s, frames) in got.iter().enumerate() {
        assert_eq!(frames.len(), 1, "stream {s}");
        assert_bit_identical(&frames[0].1, &oracle, &format!("stream {s}"));
    }
    let k_after = cache.get_or_compile(&plan.stages()[0].netlist, OpMode::Exact);
    assert!(
        Arc::ptr_eq(&k_before, &k_after),
        "64 streams must share the plan-compile-time kernel, never recompile it"
    );
    // the global counters are shared with concurrently-running tests,
    // so bound the deltas instead of pinning them: the whole binary
    // compiles only a handful of distinct netlists — nowhere near one
    // miss per stream — while the 64 worker executors must all hit
    let after = cache.stats();
    assert!(
        after.misses - before.misses < N as u64 / 2,
        "per-stream recompiles detected (misses {} -> {})",
        before.misses,
        after.misses
    );
    assert!(
        after.hits >= before.hits + N as u64,
        "every stream executor should hit the shared cache (hits {} -> {})",
        before.hits,
        after.hits
    );
}

/// Structurally different DSL programs never collide on the netlist
/// fingerprint that keys the kernel cache (names don't matter,
/// structure and constants do).
#[test]
fn structurally_different_programs_never_collide_on_fingerprint() {
    let fig12 = fpspatial::dsl::compile(
        "use float(10, 5);\ninput x, y;\noutput z;\nvar float x, y, m, s, d, z;\n\
         m = mult(x, y);\ns = adder(x, y);\nd = div(m, s);\nz = sqrt(d);",
        "fig12",
    )
    .unwrap();
    // same dataflow, different op in the middle: sub instead of adder
    let variant = fpspatial::dsl::compile(
        "use float(10, 5);\ninput x, y;\noutput z;\nvar float x, y, m, s, d, z;\n\
         m = mult(x, y);\ns = sub(x, y);\nd = div(m, s);\nz = sqrt(d);",
        "fig12_variant",
    )
    .unwrap();
    // identical structure under different identifiers: must collide
    let renamed = fpspatial::dsl::compile(
        "use float(10, 5);\ninput p, q;\noutput r;\nvar float p, q, a, b, c, r;\n\
         a = mult(p, q);\nb = adder(p, q);\nc = div(a, b);\nr = sqrt(c);",
        "fig12_renamed",
    )
    .unwrap();
    let probe = Pipeline::new().dsl(CACHE_PROBE_DSL).compile(OpMode::Exact).unwrap();
    let gauss = builtin(FilterKind::Conv3x3);

    let f0 = fig12.netlist.fingerprint();
    assert_ne!(f0, variant.netlist.fingerprint(), "op substitution must change the key");
    assert_eq!(f0, renamed.netlist.fingerprint(), "renames must share the kernel");
    assert_ne!(
        probe.stages()[0].netlist.fingerprint(),
        gauss.stages()[0].netlist.fingerprint(),
        "different coefficients must not collide"
    );
}
