//! Frame-server contract (`pipeline::FrameServer`): N independent
//! streams over ONE shared worker pool, each keeping the full
//! single-session guarantees.
//!
//! * every stream's outputs are delivered strictly in submission order
//!   and **bit-identical** to a solo [`Session`] under every
//!   [`ExecPlan`] (and to the sequential oracle) — multiplexing changes
//!   scheduling, never pixels;
//! * per-stream [`Metrics`] on a healthy run are exactly what the same
//!   stream reports running alone (all fault counters zero, delivered
//!   == submitted), and the aggregate equals the per-stream sum;
//! * geometry pinning, input validation and builder errors are
//!   per-stream and typed.
//!
//! [`Session`]: fpspatial::pipeline::Session

use std::thread;

use fpspatial::filters::FilterKind;
use fpspatial::fpcore::OpMode;
use fpspatial::pipeline::{
    CompiledPipeline, ExecError, ExecPlan, FrameServer, Pipeline, ServerEvent, SessionConfig,
    Submitted,
};
use fpspatial::video::Frame;

const EXECS: [ExecPlan; 4] = [
    ExecPlan::Scalar,
    ExecPlan::Batched,
    ExecPlan::Tiled { workers: 2 },
    ExecPlan::Streaming { workers: 2, reorder: 2 },
];

fn builtin(kind: FilterKind) -> CompiledPipeline {
    Pipeline::new().builtin(kind).compile(OpMode::Exact).unwrap()
}

fn assert_bit_identical(a: &Frame, b: &Frame, what: &str) {
    assert_eq!((a.width, a.height), (b.width, b.height), "{what}: shape");
    for (i, (x, y)) in a.data.iter().zip(&b.data).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: pixel {i}: {x} vs {y}");
    }
}

/// Partition a drained event list into per-stream (seq, frame) runs,
/// panicking on any fault.
fn by_stream(events: Vec<ServerEvent>, streams: usize) -> Vec<Vec<(u64, Frame)>> {
    let mut got: Vec<Vec<(u64, Frame)>> = vec![Vec::new(); streams];
    for ev in events {
        match ev {
            ServerEvent::Frame { stream, seq, frame, .. } => got[stream].push((seq, frame)),
            ServerEvent::Fault { stream, error } => {
                panic!("unexpected fault on stream {stream}: {error}")
            }
        }
    }
    got
}

/// The headline contract: three streams with *different* plans and
/// geometries share one pool, and each comes out in order and
/// bit-identical to a solo session under every execution plan.
#[test]
fn n_streams_are_bit_identical_to_solo_sessions_under_every_plan() {
    const F: usize = 5;
    let plans = [
        builtin(FilterKind::Median),
        builtin(FilterKind::Conv3x3),
        Pipeline::new()
            .builtin(FilterKind::Median)
            .builtin(FilterKind::FpSobel)
            .compile(OpMode::Exact)
            .unwrap(),
    ];
    let sizes = [(32, 24), (24, 16), (40, 20)];
    let inputs: Vec<Vec<Frame>> = sizes
        .iter()
        .enumerate()
        .map(|(s, &(w, h))| (0..F).map(|i| Frame::noise(w, h, (s * 100 + i) as u64)).collect())
        .collect();

    let mut server = FrameServer::builder(3)
        .stream(&plans[0], SessionConfig::new())
        .stream(&plans[1], SessionConfig::new())
        .stream(&plans[2], SessionConfig::new())
        .build()
        .unwrap();
    for i in 0..F {
        for s in 0..3 {
            let sub = server.submit(s, &inputs[s][i]).unwrap();
            assert_eq!(sub, Submitted::Queued(i as u64), "stream {s} frame {i}");
        }
    }
    let got = by_stream(server.drain().unwrap(), 3);

    for s in 0..3 {
        assert_eq!(got[s].len(), F, "stream {s} delivered every frame");
        for (i, (seq, frame)) in got[s].iter().enumerate() {
            assert_eq!(*seq, i as u64, "stream {s} delivers in submission order");
            let oracle = plans[s].run_frame_sequential(&inputs[s][i]);
            assert_bit_identical(frame, &oracle, &format!("stream {s} frame {i} vs oracle"));
        }
        for exec in EXECS {
            let mut solo = plans[s].session(exec).unwrap();
            for (i, (_, frame)) in got[s].iter().enumerate() {
                let want = solo.process(&inputs[s][i]).unwrap();
                assert_bit_identical(frame, &want, &format!("stream {s} frame {i} vs {exec}"));
            }
        }
    }
}

/// Healthy-run accounting: each stream's counters through the shared
/// pool are identical to the same stream running alone (delivered ==
/// submitted, zero faults), and the aggregate is the per-stream sum.
#[test]
fn per_stream_metrics_match_solo_runs_and_aggregate_is_their_sum() {
    const N: usize = 4;
    const F: usize = 6;
    let plan = builtin(FilterKind::Median);
    let inputs: Vec<Frame> = (0..F).map(|i| Frame::noise(32, 24, i as u64)).collect();

    let mut builder = FrameServer::builder(2);
    for _ in 0..N {
        builder = builder.stream(&plan, SessionConfig::new());
    }
    let mut server = builder.build().unwrap();
    for f in &inputs {
        for s in 0..N {
            server.submit(s, f).unwrap();
        }
    }
    let got = by_stream(server.drain().unwrap(), N);

    // solo baseline: the same frame run through its own session
    let mut solo = plan.session(ExecPlan::streaming(2)).unwrap();
    let solo_m = solo.process_sequence(inputs.clone(), |_, _| {}).unwrap();
    assert_eq!(solo_m.delivered, F as u64);
    assert_eq!((solo_m.dropped, solo_m.deadline_misses, solo_m.worker_restarts), (0, 0, 0));

    for s in 0..N {
        assert_eq!(got[s].len(), F);
        let m = server.metrics(s);
        assert_eq!(m.submitted(), F as u64, "stream {s}");
        assert_eq!(m.delivered, solo_m.delivered, "stream {s} delivered == running alone");
        assert_eq!(
            (m.dropped, m.deadline_misses, m.worker_restarts),
            (solo_m.dropped, solo_m.deadline_misses, solo_m.worker_restarts),
            "stream {s} fault counters == running alone"
        );
    }
    let a = server.aggregate();
    assert_eq!(a.submitted(), (N * F) as u64, "aggregate submissions are the sum");
    assert_eq!(a.delivered, (N * F) as u64, "aggregate deliveries are the sum");
    let sums = (0..N).fold((0u64, 0u64, 0u64), |acc, s| {
        let m = server.metrics(s);
        (acc.0 + m.dropped, acc.1 + m.deadline_misses, acc.2 + m.worker_restarts)
    });
    assert_eq!((a.dropped, a.deadline_misses, a.worker_restarts), sums);
}

/// Channel ingest: producer threads feed [`StreamSender`]s, `run`
/// schedules until they hang up — outputs still per-stream in-order and
/// oracle-identical.
///
/// [`StreamSender`]: fpspatial::pipeline::StreamSender
#[test]
fn channel_ingest_run_delivers_every_stream_in_order() {
    const N: usize = 2;
    const F: usize = 6;
    let plan = builtin(FilterKind::Conv3x3);
    let inputs: Vec<Vec<Frame>> = (0..N)
        .map(|s| (0..F).map(|i| Frame::noise(28, 20, (s * 50 + i) as u64)).collect())
        .collect();

    let mut server = FrameServer::builder(2)
        .stream(&plan, SessionConfig::new())
        .stream(&plan, SessionConfig::new())
        .build()
        .unwrap();
    let senders: Vec<_> = (0..N).map(|s| server.sender(s).unwrap()).collect();

    let mut got: Vec<Vec<(u64, Frame)>> = vec![Vec::new(); N];
    thread::scope(|scope| {
        for (s, sender) in senders.into_iter().enumerate() {
            let frames = inputs[s].clone();
            scope.spawn(move || {
                for f in frames {
                    assert!(sender.send(f), "server hung up early");
                }
            });
        }
        server.run(|ev| match ev {
            ServerEvent::Frame { stream, seq, frame, .. } => {
                got[stream].push((seq, frame));
                None
            }
            ServerEvent::Fault { stream, error } => {
                panic!("unexpected fault on stream {stream}: {error}")
            }
        })
    })
    .unwrap();

    for s in 0..N {
        assert_eq!(got[s].len(), F, "stream {s}");
        for (i, (seq, frame)) in got[s].iter().enumerate() {
            assert_eq!(*seq, i as u64, "stream {s} in order");
            let oracle = plan.run_frame_sequential(&inputs[s][i]);
            assert_bit_identical(frame, &oracle, &format!("stream {s} frame {i}"));
        }
        assert_eq!(server.metrics(s).delivered, F as u64);
    }
}

/// Geometry pinning is per-stream: a stream latches its first frame's
/// size and rejects others, without disturbing its queued work or any
/// other stream.
#[test]
fn geometry_pinning_is_per_stream() {
    let plan = builtin(FilterKind::Median);
    let mut server = FrameServer::builder(2)
        .stream(&plan, SessionConfig::new())
        .stream(&plan, SessionConfig::new())
        .build()
        .unwrap();

    server.submit(0, &Frame::noise(32, 24, 1)).unwrap();
    let err = server.submit(0, &Frame::noise(48, 32, 2)).unwrap_err();
    assert!(err.to_string().contains("pinned"), "{err}");
    // stream 1 pins independently — the size stream 0 just rejected
    server.submit(1, &Frame::noise(48, 32, 3)).unwrap();
    let got = by_stream(server.drain().unwrap(), 2);
    assert_eq!((got[0].len(), got[1].len()), (1, 1));
    assert_eq!((got[1][0].1.width, got[1][0].1.height), (48, 32));
}

/// Input validation is per-stream and typed: a non-finite frame comes
/// back as [`ExecError::PoisonFrame`] and the stream keeps serving.
#[test]
fn a_poison_frame_is_rejected_per_stream_and_the_stream_keeps_serving() {
    let plan = builtin(FilterKind::Median);
    let mut server = FrameServer::builder(1).stream(&plan, SessionConfig::new()).build().unwrap();

    let good = Frame::noise(24, 16, 7);
    server.submit(0, &good).unwrap();
    let mut bad = Frame::noise(24, 16, 8);
    bad.data[5] = f64::NAN;
    let err = server.submit(0, &bad).unwrap_err();
    match err.downcast_ref::<ExecError>() {
        Some(ExecError::PoisonFrame { frame_seq, index, .. }) => {
            assert_eq!((*frame_seq, *index), (1, 5));
        }
        other => panic!("expected PoisonFrame, got {other:?}"),
    }
    server.submit(0, &good).unwrap();
    let got = by_stream(server.drain().unwrap(), 1);
    assert_eq!(got[0].len(), 2, "both good frames delivered");
    let m = server.metrics(0);
    assert_eq!((m.submitted(), m.delivered), (2, 2));
    assert_eq!((m.dropped, m.deadline_misses, m.worker_restarts), (0, 0, 0));
}

/// Builder and addressing errors are typed and early.
#[test]
fn builder_and_addressing_errors_are_reported() {
    let plan = builtin(FilterKind::Median);
    let err = FrameServer::builder(0).stream(&plan, SessionConfig::new()).build().unwrap_err();
    assert!(err.to_string().contains("worker"), "{err}");
    let err = FrameServer::builder(2).build().unwrap_err();
    assert!(err.to_string().contains("stream"), "{err}");
    let err = FrameServer::builder(2)
        .stream_with_queue(&plan, SessionConfig::new(), 0)
        .build()
        .unwrap_err();
    assert!(err.to_string().contains("budget"), "{err}");

    let mut server = FrameServer::builder(1).stream(&plan, SessionConfig::new()).build().unwrap();
    let err = server.submit(5, &Frame::noise(24, 16, 0)).unwrap_err();
    assert!(err.to_string().contains("unknown stream"), "{err}");
    assert!(server.sender(5).is_err());
}
