//! Batched/tiled/streaming-vs-scalar parity: every [`ExecPlan`] is a pure
//! layout/scheduling change, so session outputs must be **bit-identical**
//! to the scalar path and to the plan's sequential oracle
//! (`CompiledPipeline::run_frame_sequential`) for every filter, in both
//! numeric modes, including ragged right-edge lanes (width not a multiple
//! of the lane count).  All execution goes through the unified
//! `Pipeline` → `CompiledPipeline` → `Session` API.

use fpspatial::filters::FilterKind;
use fpspatial::fpcore::{FloatFormat, OpMode};
use fpspatial::pipeline::{CompiledPipeline, ExecPlan, Pipeline};
use fpspatial::sim::LANES;
use fpspatial::video::Frame;

const F16: FloatFormat = FloatFormat::new(10, 5);

/// Each canonical DSL program paired with the built-in netlist it mirrors.
const DSL_SUITE: [(FilterKind, &str); 5] = [
    (FilterKind::Conv3x3, include_str!("../../examples/dsl/conv3x3.dsl")),
    (FilterKind::Conv5x5, include_str!("../../examples/dsl/conv5x5.dsl")),
    (FilterKind::Median, include_str!("../../examples/dsl/median.dsl")),
    (FilterKind::Nlfilter, include_str!("../../examples/dsl/nlfilter.dsl")),
    (FilterKind::FpSobel, include_str!("../../examples/dsl/sobel.dsl")),
];

/// Bitwise frame comparison (catches even 0.0 vs -0.0 divergence).
fn assert_bit_identical(a: &Frame, b: &Frame, what: &str) {
    assert_eq!((a.width, a.height), (b.width, b.height), "{what}: shape");
    for (i, (x, y)) in a.data.iter().zip(&b.data).enumerate() {
        assert_eq!(
            x.to_bits(),
            y.to_bits(),
            "{what}: pixel {i} ({}, {}) differs: {x} vs {y}",
            i % a.width,
            i / a.width
        );
    }
}

fn plan_for(kind: FilterKind, mode: OpMode) -> CompiledPipeline {
    Pipeline::new().builtin(kind).format(F16).compile(mode).unwrap()
}

/// One frame through a fresh session under `exec`.
fn run(plan: &CompiledPipeline, exec: ExecPlan, frame: &Frame) -> Frame {
    plan.session(exec).unwrap().process(frame).unwrap()
}

#[test]
fn batched_bit_identical_to_scalar_all_filters_both_modes() {
    // 37 = 2·LANES + 5 ragged tail; salt-and-pepper exercises the
    // min/max/CAS datapaths and the conv adder trees with extremes.
    assert_eq!(LANES, 16, "test widths assume 16 lanes");
    let frames = [
        Frame::test_card(37, 19),
        Frame::salt_pepper(37, 19, 0.15, 7),
    ];
    for kind in FilterKind::NETLIST {
        for mode in [OpMode::Exact, OpMode::Poly] {
            let plan = plan_for(kind, mode);
            for (i, f) in frames.iter().enumerate() {
                let oracle = plan.run_frame_sequential(f);
                let scalar = run(&plan, ExecPlan::Scalar, f);
                let batched = run(&plan, ExecPlan::Batched, f);
                let what = format!("{} {mode:?} frame{i}", kind.name());
                assert_bit_identical(&scalar, &oracle, &format!("{what} scalar"));
                assert_bit_identical(&batched, &oracle, &format!("{what} batched"));
            }
        }
    }
}

#[test]
fn batched_bit_identical_across_widths() {
    // width < LANES, width == LANES, exact multiple, multiple + 1
    for w in [7usize, 16, 32, 33] {
        let f = Frame::noise(w, 9, w as u64);
        for kind in [FilterKind::Conv3x3, FilterKind::Median] {
            let plan = plan_for(kind, OpMode::Exact);
            let scalar = run(&plan, ExecPlan::Scalar, &f);
            let batched = run(&plan, ExecPlan::Batched, &f);
            assert_bit_identical(&scalar, &batched, &format!("{} w={w}", kind.name()));
        }
    }
}

#[test]
fn conv5x5_batched_handles_wide_borders() {
    // 5x5 window: two border columns on each side interact with lane
    // chunk boundaries.
    let f = Frame::test_card(18, 11); // 18 = LANES + 2: border in chunk 2
    let plan = plan_for(FilterKind::Conv5x5, OpMode::Exact);
    let scalar = run(&plan, ExecPlan::Scalar, &f);
    let batched = run(&plan, ExecPlan::Batched, &f);
    assert_bit_identical(&scalar, &batched, "conv5x5 w=18");
}

#[test]
fn tiled_sessions_bit_identical_for_every_filter() {
    let f = Frame::test_card(45, 23);
    for kind in FilterKind::NETLIST {
        let plan = plan_for(kind, OpMode::Exact);
        let want = plan.run_frame_sequential(&f);
        for workers in [1usize, 3, 4] {
            let got = run(&plan, ExecPlan::Tiled { workers }, &f);
            assert_bit_identical(
                &got,
                &want,
                &format!("{} workers={workers}", kind.name()),
            );
        }
    }
}

#[test]
fn tiled_more_workers_than_rows() {
    let f = Frame::gradient(20, 5);
    let plan = plan_for(FilterKind::Median, OpMode::Exact);
    let want = plan.run_frame_sequential(&f);
    let got = run(&plan, ExecPlan::Tiled { workers: 32 }, &f);
    assert_bit_identical(&got, &want, "workers>rows");
}

#[test]
fn streaming_session_bit_identical_to_oracle() {
    let plan = plan_for(FilterKind::FpSobel, OpMode::Exact);
    let frames: Vec<Frame> = (0..5).map(|i| Frame::noise(29, 13, i)).collect();
    let mut session = plan.session(ExecPlan::Streaming { workers: 3, reorder: 4 }).unwrap();
    let mut outs = Vec::new();
    let m = session.process_sequence(frames.clone(), |_, f| outs.push(f)).unwrap();
    assert_eq!(m.frames, 5);
    assert!(m.p99_latency <= m.max_latency);
    for (f, got) in frames.iter().zip(&outs) {
        assert_bit_identical(got, &plan.run_frame_sequential(f), "pipeline frame");
    }
}

/// The tentpole parity claim: every canonical DSL program is bitwise
/// identical to the built-in netlist it mirrors through every execution
/// plan, in both numeric modes.
#[test]
fn dsl_programs_bit_identical_to_builtins_all_plans_both_modes() {
    // 37 = 2·LANES + 5 ragged tail; salt-and-pepper hits the CAS/minmax
    // datapaths with extremes.
    let frames = [
        Frame::test_card(37, 19),
        Frame::salt_pepper(37, 19, 0.15, 11),
    ];
    for (kind, src) in DSL_SUITE {
        for mode in [OpMode::Exact, OpMode::Poly] {
            let builtin = plan_for(kind, mode);
            let dsl =
                Pipeline::new().dsl_named(src, kind.name()).compile(mode).unwrap();
            let (bhw, dhw) = (&builtin.stages()[0], &dsl.stages()[0]);
            assert_eq!(dhw.fmt, bhw.fmt, "{}", kind.name());
            assert_eq!(dhw.geom, bhw.geom, "{}", kind.name());
            assert_eq!(dsl.datapath_latency(), builtin.datapath_latency(), "{}", kind.name());
            for (i, f) in frames.iter().enumerate() {
                let want = builtin.run_frame_sequential(f);
                for exec in [
                    ExecPlan::Scalar,
                    ExecPlan::Batched,
                    ExecPlan::Tiled { workers: 3 },
                    ExecPlan::streaming(2),
                ] {
                    let got = run(&dsl, exec, f);
                    assert_bit_identical(
                        &got,
                        &want,
                        &format!("dsl {} {mode:?} frame{i} {exec}", kind.name()),
                    );
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Naive nested-loop references for the CNN-shaped stage vocabulary:
// rectangular windows, output stride, channel planes, relu and max-pool.
// The reference recomputes every output pixel straight from the input
// frame with explicit clamped gather loops — no line buffers, no row
// bands, no lanes — and every execution plan must match it bit for bit.
// ---------------------------------------------------------------------------

mod naive {
    use fpspatial::fpcore::ops::FpOps;
    use fpspatial::fpcore::{quantize, FloatFormat};
    use fpspatial::video::{Frame, StageGeometry};

    /// Quantize every pixel into `fmt` (the hardware stream carries
    /// format values; pre-quantizing makes the comparison exact).
    pub fn qframe(f: &Frame, fmt: FloatFormat) -> Frame {
        Frame {
            width: f.width,
            height: f.height,
            data: f.data.iter().map(|&v| quantize(v, fmt)).collect(),
        }
    }

    /// Replicate-clamped window gather for output pixel `(ox, oy)` in
    /// raster order (`w00 w01 .. w10 ..`).  `oy` spans the stacked
    /// channel planes; plane-local coordinates scale by the stride and
    /// clamp at the *plane* borders, never across them.
    pub fn gather(f: &Frame, g: StageGeometry, ox: usize, oy: usize) -> Vec<f64> {
        let plane_h = f.height / g.channels;
        let out_ph = plane_h.div_ceil(g.stride);
        let (plane, opy) = (oy / out_ph, oy % out_ph);
        let (cy, cx) = (opy * g.stride, ox * g.stride);
        let mut vals = Vec::with_capacity(g.win_h * g.win_w);
        for r in 0..g.win_h {
            let iy = (cy + r) as isize - g.p_top() as isize;
            let iy = iy.clamp(0, plane_h as isize - 1) as usize;
            for c in 0..g.win_w {
                let ix = (cx + c) as isize - g.p_left() as isize;
                let ix = ix.clamp(0, f.width as isize - 1) as usize;
                vals.push(f.get(ix, plane * plane_h + iy));
            }
        }
        vals
    }

    /// The paper's recursive `AdderTree(N)` summation order, scalar.
    pub fn tree_sum(ops: &FpOps, terms: &[f64]) -> f64 {
        if terms.len() == 1 {
            return terms[0];
        }
        let n = terms.len();
        let n0 = 1usize << (usize::BITS - 1 - n.leading_zeros());
        if n0 == n {
            let mut level = terms.to_vec();
            while level.len() > 1 {
                level = level.chunks(2).map(|p| ops.add(p[0], p[1])).collect();
            }
            level[0]
        } else {
            let (lo, hi) = (tree_sum(ops, &terms[..n0]), tree_sum(ops, &terms[n0..]));
            ops.add(lo, hi)
        }
    }

    /// One stage the slow way: nested loops over every output pixel.
    pub fn stage(f: &Frame, g: StageGeometry, eval: impl Fn(&[f64]) -> f64) -> Frame {
        Frame::from_fn(g.out_width(f.width), g.out_height(f.height), |ox, oy| {
            eval(&gather(f, g, ox, oy))
        })
    }

    /// Naive convolution: per-tap rounded multiply, then the adder tree.
    pub fn conv(f: &Frame, g: StageGeometry, kern: &[f64], ops: &FpOps) -> Frame {
        let kq: Vec<f64> = kern.iter().map(|&k| quantize(k, ops.fmt)).collect();
        stage(f, g, |vals| {
            let prods: Vec<f64> =
                vals.iter().zip(&kq).map(|(&v, &k)| ops.mul(v, k)).collect();
            tree_sum(ops, &prods)
        })
    }

    /// Naive max-pool: raster-order left fold of IEEE max.
    pub fn max_pool(f: &Frame, g: StageGeometry) -> Frame {
        stage(f, g, |vals| vals[1..].iter().fold(vals[0], |a, &b| a.max(b)))
    }
}

const ALL_PLANS: [ExecPlan; 4] = [
    ExecPlan::Scalar,
    ExecPlan::Batched,
    ExecPlan::Tiled { workers: 3 },
    ExecPlan::Streaming { workers: 2, reorder: 2 },
];

#[test]
fn rect_conv_matches_naive_reference_all_plans_both_modes() {
    use fpspatial::filters::HwFilter;
    use fpspatial::fpcore::ops::FpOps;
    // 3×5 box: a genuinely rectangular window over a ragged width
    // (37 = 2·LANES + 5)
    let kern = [1.0 / 15.0; 15];
    let hw = HwFilter::conv_rect(F16, 3, 5, &kern).unwrap();
    let f = naive::qframe(&Frame::noise(37, 19, 42), F16);
    for mode in [OpMode::Exact, OpMode::Poly] {
        let plan = Pipeline::from_stages([hw.clone()]).compile(mode).unwrap();
        let ops = FpOps::with_mode(F16, mode);
        let want = naive::conv(&f, hw.geom, &kern, &ops);
        assert_eq!((want.width, want.height), (37, 19));
        for exec in ALL_PLANS {
            let got = run(&plan, exec, &f);
            assert_bit_identical(&got, &want, &format!("conv3x5 {mode:?} {exec}"));
        }
    }
}

#[test]
fn strided_conv_shrinks_output_and_matches_naive() {
    use fpspatial::filters::{conv, HwFilter};
    use fpspatial::fpcore::ops::FpOps;
    // stride 2 over ragged 33×19: output is ceil-mode 17×10
    let hw = HwFilter::new(FilterKind::Conv3x3, F16).unwrap().with_stride(2);
    let f = naive::qframe(&Frame::noise(33, 19, 7), F16);
    for mode in [OpMode::Exact, OpMode::Poly] {
        let plan = Pipeline::from_stages([hw.clone()]).compile(mode).unwrap();
        assert_eq!(plan.output_dims(33, 19), (17, 10));
        let ops = FpOps::with_mode(F16, mode);
        let want = naive::conv(&f, hw.geom, &conv::gaussian3x3(), &ops);
        assert_eq!((want.width, want.height), (17, 10));
        for exec in ALL_PLANS {
            let got = run(&plan, exec, &f);
            assert_bit_identical(&got, &want, &format!("conv3x3/s2 {mode:?} {exec}"));
        }
    }
}

#[test]
fn maxpool_matches_naive_raster_fold() {
    use fpspatial::filters::HwFilter;
    // classic 2×2/s2 (even window, top-left aligned, ceil mode) and an
    // overlapping 3×3/s2, both over salt-and-pepper extremes
    let f = naive::qframe(&Frame::salt_pepper(37, 19, 0.2, 3), F16);
    for (k, s, dims) in [(2usize, 2usize, (19usize, 10usize)), (3, 2, (19, 10))] {
        let hw = HwFilter::max_pool(F16, k, s).unwrap();
        let plan = Pipeline::from_stages([hw.clone()]).compile(OpMode::Exact).unwrap();
        assert_eq!(plan.output_dims(37, 19), dims);
        let want = naive::max_pool(&f, hw.geom);
        assert_eq!((want.width, want.height), dims);
        for exec in ALL_PLANS {
            let got = run(&plan, exec, &f);
            assert_bit_identical(&got, &want, &format!("maxpool{k}s{s} {exec}"));
        }
    }
}

#[test]
fn relu_over_channel_planes_matches_naive() {
    use fpspatial::filters::HwFilter;
    // 3 independent signed planes stacked vertically (height 3·6)
    let hw = HwFilter::relu(F16).with_channels(3);
    let signed = Frame::from_fn(23, 18, |x, y| ((x * 7 + y * 13) % 31) as f64 - 15.0);
    let f = naive::qframe(&signed, F16);
    let plan = Pipeline::from_stages([hw.clone()]).compile(OpMode::Exact).unwrap();
    let want = naive::stage(&f, hw.geom, |vals| vals[0].max(0.0));
    assert_eq!((want.width, want.height), (23, 18));
    assert!(want.data.iter().all(|&v| v >= 0.0));
    for exec in ALL_PLANS {
        let got = run(&plan, exec, &f);
        assert_bit_identical(&got, &want, &format!("relu x3ch {exec}"));
    }
}

#[test]
fn windowed_stage_clamps_at_plane_borders_not_across_them() {
    use fpspatial::filters::{conv, HwFilter};
    use fpspatial::fpcore::ops::FpOps;
    // two planes with very different content: any cross-plane leak at
    // the seam row diverges from the per-plane naive gather
    let hw = HwFilter::new(FilterKind::Conv3x3, F16).unwrap().with_channels(2);
    let src = Frame::from_fn(21, 24, |x, y| {
        if y < 12 {
            (x + y) as f64
        } else {
            200.0 - x as f64
        }
    });
    let f = naive::qframe(&src, F16);
    let plan = Pipeline::from_stages([hw.clone()]).compile(OpMode::Exact).unwrap();
    let ops = FpOps::exact(F16);
    let want = naive::conv(&f, hw.geom, &conv::gaussian3x3(), &ops);
    for exec in ALL_PLANS {
        let got = run(&plan, exec, &f);
        assert_bit_identical(&got, &want, &format!("conv3x3 x2ch {exec}"));
    }
}

#[test]
fn cnn_chain_matches_naive_stage_folding() {
    use fpspatial::filters::conv;
    use fpspatial::fpcore::ops::FpOps;
    use fpspatial::fpcore::quantize;
    use fpspatial::video::StageGeometry;
    // conv3x3[f24] -> relu[f24] -> maxpool2x2/s2[f16]: a mixed-format
    // CNN tail with an explicit 24->16 converter before the pool
    let f24 = FloatFormat::new(16, 7);
    let src = naive::qframe(&Frame::test_card(37, 19), f24);
    for mode in [OpMode::Exact, OpMode::Poly] {
        let plan = Pipeline::new()
            .builtin(FilterKind::Conv3x3)
            .format(f24)
            .relu()
            .format(f24)
            .max_pool(2, 2)
            .format(F16)
            .compile(mode)
            .unwrap();
        assert_eq!(plan.output_dims(37, 19), (19, 10));
        let ops24 = FpOps::with_mode(f24, mode);
        let a = naive::conv(&src, StageGeometry::square(3), &conv::gaussian3x3(), &ops24);
        let b = naive::stage(&a, StageGeometry::square(1), |v| v[0].max(0.0));
        let c = naive::qframe(&b, F16); // the 24->16 boundary converter
        let want = naive::max_pool(&c, StageGeometry::square(2).with_stride(2));
        assert_eq!((want.width, want.height), (19, 10));
        for exec in ALL_PLANS {
            let got = run(&plan, exec, &src);
            assert_bit_identical(&got, &want, &format!("cnn chain {mode:?} {exec}"));
        }
    }
}

/// The acceptance chain: the checked-in VGG-style descriptor
/// (conv→relu→conv→relu→maxpool, per-layer formats) runs under all four
/// execution plans bit-identical to the naive nested-loop scalar
/// reference, with the stride-shrunk output dimensions asserted.
#[test]
fn vgg_descriptor_pipeline_matches_naive_under_every_plan() {
    use fpspatial::filters::conv;
    use fpspatial::fpcore::ops::FpOps;
    use fpspatial::pipeline::parse_net;
    use fpspatial::video::StageGeometry;
    let src = include_str!("../../examples/net/vgg_block.net");
    let f24 = FloatFormat::new(16, 7);
    let f10 = FloatFormat::new(10, 5);
    let input = naive::qframe(&Frame::test_card(37, 19), f24);
    for mode in [OpMode::Exact, OpMode::Poly] {
        let plan = parse_net(src, None).unwrap().compile(mode).unwrap();
        assert_eq!(plan.len(), 5);
        assert!(plan.is_mixed_format());
        assert_eq!(plan.output_dims(37, 19), (19, 10));
        // conv[24] -> relu[24] -> (24→16 convert) -> conv[16] -> relu[16]
        // -> maxpool2x2/s2[16], every stage as explicit nested loops
        let g3 = StageGeometry::square(3);
        let g1 = StageGeometry::square(1);
        let ops24 = FpOps::with_mode(f24, mode);
        let ops10 = FpOps::with_mode(f10, mode);
        let a = naive::conv(&input, g3, &conv::gaussian3x3(), &ops24);
        let b = naive::stage(&a, g1, |v| v[0].max(0.0));
        let c = naive::qframe(&b, f10);
        let d = naive::conv(&c, g3, &conv::gaussian3x3(), &ops10);
        let e = naive::stage(&d, g1, |v| v[0].max(0.0));
        let want = naive::max_pool(&e, StageGeometry::square(2).with_stride(2));
        assert_eq!((want.width, want.height), (19, 10));
        for exec in ALL_PLANS {
            let got = run(&plan, exec, &input);
            assert_bit_identical(&got, &want, &format!("vgg_block.net {mode:?} {exec}"));
        }
    }
}

/// A long-lived DSL-filter session streams a whole sequence unchanged.
#[test]
fn dsl_filter_through_streaming_session() {
    let (kind, src) = (FilterKind::Nlfilter, DSL_SUITE[3].1);
    let builtin = plan_for(kind, OpMode::Exact);
    let dsl = Pipeline::new().dsl_named(src, "nlfilter_dsl").compile(OpMode::Exact).unwrap();
    let frames: Vec<Frame> = (0..6).map(|i| Frame::noise(33, 14, 100 + i)).collect();
    let mut session = dsl.session(ExecPlan::streaming(3)).unwrap();
    let mut outs = Vec::new();
    let m = session.process_sequence(frames.clone(), |_, f| outs.push(f)).unwrap();
    assert_eq!(m.frames, 6);
    for (f, got) in frames.iter().zip(&outs) {
        assert_bit_identical(got, &builtin.run_frame_sequential(f), "dsl pipeline frame");
    }
}

// ---------------------------------------------------------------------------
// Compiled-kernel arm: drive the fused direct-threaded kernel straight
// through `eval_band_kernel` — no session, no pool — mirroring
// `run_frame_sequential`'s stage loop, and require bit-identity with that
// oracle for every canonical DSL program and the VGG descriptor in both
// numeric modes.  The four `ExecPlan`s (whose batched paths now execute
// the same compiled kernels) must agree with both arms.
// ---------------------------------------------------------------------------

/// `run_frame_sequential`, but each stage evaluated by the fused
/// [`KernelExec`] instead of the scalar tape interpreter.
fn run_frame_kernel(plan: &CompiledPipeline, mode: OpMode, frame: &Frame) -> Frame {
    use fpspatial::filters::eval_band_kernel;
    use fpspatial::sim::KernelExec;
    use fpspatial::video::WindowGenerator;
    let converters = plan.converters();
    let mut cur: Option<Frame> = None;
    for (i, hw) in plan.stages().iter().enumerate() {
        let src = cur.as_ref().unwrap_or(frame);
        let (ow, oh) = hw.output_dims(src.width, src.height);
        let mut out = Frame::new(ow, oh);
        let mut eng = KernelExec::for_netlist(&hw.netlist, mode);
        let mut gen = WindowGenerator::with_geometry(hw.geom, src.width).unwrap();
        eval_band_kernel(&mut eng, &mut gen, src, 0, oh, &mut out.data);
        if let Some(Some(cvt)) = converters.get(i) {
            cvt.apply_row(&mut out.data);
        }
        cur = Some(out);
    }
    cur.expect("plans have at least one stage")
}

#[test]
fn compiled_kernel_bit_identical_to_sequential_oracle_every_dsl_program() {
    let frames = [
        Frame::test_card(37, 19),
        Frame::salt_pepper(37, 19, 0.15, 23),
    ];
    for (kind, src) in DSL_SUITE {
        for mode in [OpMode::Exact, OpMode::Poly] {
            let plan =
                Pipeline::new().dsl_named(src, kind.name()).compile(mode).unwrap();
            for (i, f) in frames.iter().enumerate() {
                let what = format!("kernel {} {mode:?} frame{i}", kind.name());
                let oracle = plan.run_frame_sequential(f);
                let kern = run_frame_kernel(&plan, mode, f);
                assert_bit_identical(&kern, &oracle, &what);
                for exec in ALL_PLANS {
                    let got = run(&plan, exec, f);
                    assert_bit_identical(&got, &kern, &format!("{what} vs {exec}"));
                }
            }
        }
    }
}

#[test]
fn compiled_kernel_bit_identical_to_sequential_oracle_vgg_descriptor() {
    use fpspatial::pipeline::parse_net;
    let src = include_str!("../../examples/net/vgg_block.net");
    let f = Frame::test_card(37, 19);
    for mode in [OpMode::Exact, OpMode::Poly] {
        let plan = parse_net(src, None).unwrap().compile(mode).unwrap();
        let oracle = plan.run_frame_sequential(&f);
        let kern = run_frame_kernel(&plan, mode, &f);
        assert_bit_identical(&kern, &oracle, &format!("kernel vgg {mode:?}"));
        for exec in ALL_PLANS {
            let got = run(&plan, exec, &f);
            assert_bit_identical(&got, &kern, &format!("kernel vgg {mode:?} vs {exec}"));
        }
    }
}
