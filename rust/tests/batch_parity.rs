//! Batched-vs-scalar and tiled-vs-serial parity: the lane-batched engine
//! and the intra-frame tiled coordinator are pure layout/scheduling
//! changes, so their outputs must be **bit-identical** to the scalar
//! serial path for every filter, in both numeric modes, including ragged
//! right-edge lanes (width not a multiple of the lane count).

use fpspatial::coordinator::{run_frame_tiled, run_pipeline, PipelineConfig, TileConfig};
use fpspatial::filters::{FilterKind, HwFilter};
use fpspatial::fpcore::{FloatFormat, OpMode};
use fpspatial::sim::LANES;
use fpspatial::video::Frame;

const F16: FloatFormat = FloatFormat::new(10, 5);

/// Each canonical DSL program paired with the built-in netlist it mirrors.
const DSL_SUITE: [(FilterKind, &str); 5] = [
    (FilterKind::Conv3x3, include_str!("../../examples/dsl/conv3x3.dsl")),
    (FilterKind::Conv5x5, include_str!("../../examples/dsl/conv5x5.dsl")),
    (FilterKind::Median, include_str!("../../examples/dsl/median.dsl")),
    (FilterKind::Nlfilter, include_str!("../../examples/dsl/nlfilter.dsl")),
    (FilterKind::FpSobel, include_str!("../../examples/dsl/sobel.dsl")),
];

/// Bitwise frame comparison (catches even 0.0 vs -0.0 divergence).
fn assert_bit_identical(a: &Frame, b: &Frame, what: &str) {
    assert_eq!((a.width, a.height), (b.width, b.height), "{what}: shape");
    for (i, (x, y)) in a.data.iter().zip(&b.data).enumerate() {
        assert_eq!(
            x.to_bits(),
            y.to_bits(),
            "{what}: pixel {i} ({}, {}) differs: {x} vs {y}",
            i % a.width,
            i / a.width
        );
    }
}

fn parity_filters() -> Vec<FilterKind> {
    FilterKind::NETLIST.to_vec()
}

#[test]
fn batched_bit_identical_to_scalar_all_filters_both_modes() {
    // 37 = 2·LANES + 5 ragged tail; salt-and-pepper exercises the
    // min/max/CAS datapaths and the conv adder trees with extremes.
    assert_eq!(LANES, 16, "test widths assume 16 lanes");
    let frames = [
        Frame::test_card(37, 19),
        Frame::salt_pepper(37, 19, 0.15, 7),
    ];
    for kind in parity_filters() {
        let hw = HwFilter::new(kind, F16).unwrap();
        for mode in [OpMode::Exact, OpMode::Poly] {
            for (i, f) in frames.iter().enumerate() {
                let scalar = hw.run_frame(f, mode);
                let batched = hw.run_frame_batched(f, mode);
                assert_bit_identical(
                    &scalar,
                    &batched,
                    &format!("{} {mode:?} frame{i}", kind.name()),
                );
            }
        }
    }
}

#[test]
fn batched_bit_identical_across_widths() {
    // width < LANES, width == LANES, exact multiple, multiple + 1
    for w in [7usize, 16, 32, 33] {
        let f = Frame::noise(w, 9, w as u64);
        for kind in [FilterKind::Conv3x3, FilterKind::Median] {
            let hw = HwFilter::new(kind, F16).unwrap();
            let scalar = hw.run_frame(&f, OpMode::Exact);
            let batched = hw.run_frame_batched(&f, OpMode::Exact);
            assert_bit_identical(&scalar, &batched, &format!("{} w={w}", kind.name()));
        }
    }
}

#[test]
fn conv5x5_batched_handles_wide_borders() {
    // 5x5 window: two border columns on each side interact with lane
    // chunk boundaries.
    let f = Frame::test_card(18, 11); // 18 = LANES + 2: border in chunk 2
    let hw = HwFilter::new(FilterKind::Conv5x5, F16).unwrap();
    let scalar = hw.run_frame(&f, OpMode::Exact);
    let batched = hw.run_frame_batched(&f, OpMode::Exact);
    assert_bit_identical(&scalar, &batched, "conv5x5 w=18");
}

#[test]
fn tiled_coordinator_bit_identical_for_every_filter() {
    let f = Frame::test_card(45, 23);
    for kind in parity_filters() {
        let hw = HwFilter::new(kind, F16).unwrap();
        let want = hw.run_frame(&f, OpMode::Exact);
        for workers in [1usize, 3, 4] {
            for batched in [false, true] {
                let cfg = TileConfig { workers, mode: OpMode::Exact, batched };
                let got = run_frame_tiled(&hw, &f, &cfg);
                assert_bit_identical(
                    &got,
                    &want,
                    &format!("{} workers={workers} batched={batched}", kind.name()),
                );
            }
        }
    }
}

#[test]
fn tiled_more_workers_than_rows() {
    let f = Frame::gradient(20, 5);
    let hw = HwFilter::new(FilterKind::Median, F16).unwrap();
    let want = hw.run_frame(&f, OpMode::Exact);
    let cfg = TileConfig { workers: 32, mode: OpMode::Exact, batched: true };
    let got = run_frame_tiled(&hw, &f, &cfg);
    assert_bit_identical(&got, &want, "workers>rows");
}

#[test]
fn batched_pipeline_bit_identical_to_serial() {
    let hw = HwFilter::new(FilterKind::FpSobel, F16).unwrap();
    let frames: Vec<Frame> = (0..5).map(|i| Frame::noise(29, 13, i)).collect();
    let cfg = PipelineConfig { workers: 3, batched: true, ..Default::default() };
    let (outs, m) = run_pipeline(&hw, frames.clone(), &cfg).unwrap();
    assert_eq!(m.frames, 5);
    assert!(m.p99_latency <= m.max_latency);
    for (f, got) in frames.iter().zip(&outs) {
        let want = hw.run_frame(f, OpMode::Exact);
        assert_bit_identical(got, &want, "pipeline frame");
    }
}

/// The tentpole parity claim: every canonical DSL program is bitwise
/// identical to the built-in netlist it mirrors through the scalar,
/// lane-batched and tiled paths, in both numeric modes.
#[test]
fn dsl_programs_bit_identical_to_builtins_all_paths_both_modes() {
    // 37 = 2·LANES + 5 ragged tail; salt-and-pepper hits the CAS/minmax
    // datapaths with extremes.
    let frames = [
        Frame::test_card(37, 19),
        Frame::salt_pepper(37, 19, 0.15, 11),
    ];
    for (kind, src) in DSL_SUITE {
        let builtin = HwFilter::new(kind, F16).unwrap();
        let dsl = HwFilter::from_dsl(src, kind.name(), None).unwrap();
        assert_eq!(dsl.fmt, builtin.fmt, "{}", kind.name());
        assert_eq!(dsl.ksize, builtin.ksize, "{}", kind.name());
        assert_eq!(dsl.latency(), builtin.latency(), "{}", kind.name());
        for mode in [OpMode::Exact, OpMode::Poly] {
            for (i, f) in frames.iter().enumerate() {
                let want = builtin.run_frame(f, mode);
                let scalar = dsl.run_frame(f, mode);
                assert_bit_identical(
                    &scalar,
                    &want,
                    &format!("dsl {} {mode:?} frame{i} scalar", kind.name()),
                );
                let batched = dsl.run_frame_batched(f, mode);
                assert_bit_identical(
                    &batched,
                    &want,
                    &format!("dsl {} {mode:?} frame{i} batched", kind.name()),
                );
                for batched_tile in [false, true] {
                    let cfg = TileConfig { workers: 3, mode, batched: batched_tile };
                    let tiled = run_frame_tiled(&dsl, f, &cfg);
                    assert_bit_identical(
                        &tiled,
                        &want,
                        &format!(
                            "dsl {} {mode:?} frame{i} tiled batched={batched_tile}",
                            kind.name()
                        ),
                    );
                }
            }
        }
    }
}

/// DSL filters stream through the multi-worker frame pipeline unchanged.
#[test]
fn dsl_filter_through_streaming_pipeline() {
    let (kind, src) = (FilterKind::Nlfilter, DSL_SUITE[3].1);
    let builtin = HwFilter::new(kind, F16).unwrap();
    let dsl = HwFilter::from_dsl(src, "nlfilter_dsl", None).unwrap();
    let frames: Vec<Frame> = (0..6).map(|i| Frame::noise(33, 14, 100 + i)).collect();
    let cfg = PipelineConfig { workers: 3, batched: true, ..Default::default() };
    let (outs, m) = run_pipeline(&dsl, frames.clone(), &cfg).unwrap();
    assert_eq!(m.frames, 6);
    for (f, got) in frames.iter().zip(&outs) {
        let want = builtin.run_frame(f, OpMode::Exact);
        assert_bit_identical(got, &want, "dsl pipeline frame");
    }
}
