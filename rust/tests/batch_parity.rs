//! Batched-vs-scalar and tiled-vs-serial parity: the lane-batched engine
//! and the intra-frame tiled coordinator are pure layout/scheduling
//! changes, so their outputs must be **bit-identical** to the scalar
//! serial path for every filter, in both numeric modes, including ragged
//! right-edge lanes (width not a multiple of the lane count).

use fpspatial::coordinator::{run_frame_tiled, run_pipeline, PipelineConfig, TileConfig};
use fpspatial::filters::{FilterKind, HwFilter};
use fpspatial::fpcore::{FloatFormat, OpMode};
use fpspatial::sim::LANES;
use fpspatial::video::Frame;

const F16: FloatFormat = FloatFormat::new(10, 5);

/// Bitwise frame comparison (catches even 0.0 vs -0.0 divergence).
fn assert_bit_identical(a: &Frame, b: &Frame, what: &str) {
    assert_eq!((a.width, a.height), (b.width, b.height), "{what}: shape");
    for (i, (x, y)) in a.data.iter().zip(&b.data).enumerate() {
        assert_eq!(
            x.to_bits(),
            y.to_bits(),
            "{what}: pixel {i} ({}, {}) differs: {x} vs {y}",
            i % a.width,
            i / a.width
        );
    }
}

fn parity_filters() -> Vec<FilterKind> {
    FilterKind::NETLIST.to_vec()
}

#[test]
fn batched_bit_identical_to_scalar_all_filters_both_modes() {
    // 37 = 2·LANES + 5 ragged tail; salt-and-pepper exercises the
    // min/max/CAS datapaths and the conv adder trees with extremes.
    assert_eq!(LANES, 16, "test widths assume 16 lanes");
    let frames = [
        Frame::test_card(37, 19),
        Frame::salt_pepper(37, 19, 0.15, 7),
    ];
    for kind in parity_filters() {
        let hw = HwFilter::new(kind, F16);
        for mode in [OpMode::Exact, OpMode::Poly] {
            for (i, f) in frames.iter().enumerate() {
                let scalar = hw.run_frame(f, mode);
                let batched = hw.run_frame_batched(f, mode);
                assert_bit_identical(
                    &scalar,
                    &batched,
                    &format!("{} {mode:?} frame{i}", kind.name()),
                );
            }
        }
    }
}

#[test]
fn batched_bit_identical_across_widths() {
    // width < LANES, width == LANES, exact multiple, multiple + 1
    for w in [7usize, 16, 32, 33] {
        let f = Frame::noise(w, 9, w as u64);
        for kind in [FilterKind::Conv3x3, FilterKind::Median] {
            let hw = HwFilter::new(kind, F16);
            let scalar = hw.run_frame(&f, OpMode::Exact);
            let batched = hw.run_frame_batched(&f, OpMode::Exact);
            assert_bit_identical(&scalar, &batched, &format!("{} w={w}", kind.name()));
        }
    }
}

#[test]
fn conv5x5_batched_handles_wide_borders() {
    // 5x5 window: two border columns on each side interact with lane
    // chunk boundaries.
    let f = Frame::test_card(18, 11); // 18 = LANES + 2: border in chunk 2
    let hw = HwFilter::new(FilterKind::Conv5x5, F16);
    let scalar = hw.run_frame(&f, OpMode::Exact);
    let batched = hw.run_frame_batched(&f, OpMode::Exact);
    assert_bit_identical(&scalar, &batched, "conv5x5 w=18");
}

#[test]
fn tiled_coordinator_bit_identical_for_every_filter() {
    let f = Frame::test_card(45, 23);
    for kind in parity_filters() {
        let hw = HwFilter::new(kind, F16);
        let want = hw.run_frame(&f, OpMode::Exact);
        for workers in [1usize, 3, 4] {
            for batched in [false, true] {
                let cfg = TileConfig { workers, mode: OpMode::Exact, batched };
                let got = run_frame_tiled(&hw, &f, &cfg);
                assert_bit_identical(
                    &got,
                    &want,
                    &format!("{} workers={workers} batched={batched}", kind.name()),
                );
            }
        }
    }
}

#[test]
fn tiled_more_workers_than_rows() {
    let f = Frame::gradient(20, 5);
    let hw = HwFilter::new(FilterKind::Median, F16);
    let want = hw.run_frame(&f, OpMode::Exact);
    let cfg = TileConfig { workers: 32, mode: OpMode::Exact, batched: true };
    let got = run_frame_tiled(&hw, &f, &cfg);
    assert_bit_identical(&got, &want, "workers>rows");
}

#[test]
fn batched_pipeline_bit_identical_to_serial() {
    let hw = HwFilter::new(FilterKind::FpSobel, F16);
    let frames: Vec<Frame> = (0..5).map(|i| Frame::noise(29, 13, i)).collect();
    let cfg = PipelineConfig { workers: 3, batched: true, ..Default::default() };
    let (outs, m) = run_pipeline(&hw, frames.clone(), &cfg).unwrap();
    assert_eq!(m.frames, 5);
    assert!(m.p99_latency <= m.max_latency);
    for (f, got) in frames.iter().zip(&outs) {
        let want = hw.run_frame(f, OpMode::Exact);
        assert_bit_identical(got, &want, "pipeline frame");
    }
}
