//! Batched/tiled/streaming-vs-scalar parity: every [`ExecPlan`] is a pure
//! layout/scheduling change, so session outputs must be **bit-identical**
//! to the scalar path and to the plan's sequential oracle
//! (`CompiledPipeline::run_frame_sequential`) for every filter, in both
//! numeric modes, including ragged right-edge lanes (width not a multiple
//! of the lane count).  All execution goes through the unified
//! `Pipeline` → `CompiledPipeline` → `Session` API.

use fpspatial::filters::FilterKind;
use fpspatial::fpcore::{FloatFormat, OpMode};
use fpspatial::pipeline::{CompiledPipeline, ExecPlan, Pipeline};
use fpspatial::sim::LANES;
use fpspatial::video::Frame;

const F16: FloatFormat = FloatFormat::new(10, 5);

/// Each canonical DSL program paired with the built-in netlist it mirrors.
const DSL_SUITE: [(FilterKind, &str); 5] = [
    (FilterKind::Conv3x3, include_str!("../../examples/dsl/conv3x3.dsl")),
    (FilterKind::Conv5x5, include_str!("../../examples/dsl/conv5x5.dsl")),
    (FilterKind::Median, include_str!("../../examples/dsl/median.dsl")),
    (FilterKind::Nlfilter, include_str!("../../examples/dsl/nlfilter.dsl")),
    (FilterKind::FpSobel, include_str!("../../examples/dsl/sobel.dsl")),
];

/// Bitwise frame comparison (catches even 0.0 vs -0.0 divergence).
fn assert_bit_identical(a: &Frame, b: &Frame, what: &str) {
    assert_eq!((a.width, a.height), (b.width, b.height), "{what}: shape");
    for (i, (x, y)) in a.data.iter().zip(&b.data).enumerate() {
        assert_eq!(
            x.to_bits(),
            y.to_bits(),
            "{what}: pixel {i} ({}, {}) differs: {x} vs {y}",
            i % a.width,
            i / a.width
        );
    }
}

fn plan_for(kind: FilterKind, mode: OpMode) -> CompiledPipeline {
    Pipeline::new().builtin(kind).format(F16).compile(mode).unwrap()
}

/// One frame through a fresh session under `exec`.
fn run(plan: &CompiledPipeline, exec: ExecPlan, frame: &Frame) -> Frame {
    plan.session(exec).unwrap().process(frame).unwrap()
}

#[test]
fn batched_bit_identical_to_scalar_all_filters_both_modes() {
    // 37 = 2·LANES + 5 ragged tail; salt-and-pepper exercises the
    // min/max/CAS datapaths and the conv adder trees with extremes.
    assert_eq!(LANES, 16, "test widths assume 16 lanes");
    let frames = [
        Frame::test_card(37, 19),
        Frame::salt_pepper(37, 19, 0.15, 7),
    ];
    for kind in FilterKind::NETLIST {
        for mode in [OpMode::Exact, OpMode::Poly] {
            let plan = plan_for(kind, mode);
            for (i, f) in frames.iter().enumerate() {
                let oracle = plan.run_frame_sequential(f);
                let scalar = run(&plan, ExecPlan::Scalar, f);
                let batched = run(&plan, ExecPlan::Batched, f);
                let what = format!("{} {mode:?} frame{i}", kind.name());
                assert_bit_identical(&scalar, &oracle, &format!("{what} scalar"));
                assert_bit_identical(&batched, &oracle, &format!("{what} batched"));
            }
        }
    }
}

#[test]
fn batched_bit_identical_across_widths() {
    // width < LANES, width == LANES, exact multiple, multiple + 1
    for w in [7usize, 16, 32, 33] {
        let f = Frame::noise(w, 9, w as u64);
        for kind in [FilterKind::Conv3x3, FilterKind::Median] {
            let plan = plan_for(kind, OpMode::Exact);
            let scalar = run(&plan, ExecPlan::Scalar, &f);
            let batched = run(&plan, ExecPlan::Batched, &f);
            assert_bit_identical(&scalar, &batched, &format!("{} w={w}", kind.name()));
        }
    }
}

#[test]
fn conv5x5_batched_handles_wide_borders() {
    // 5x5 window: two border columns on each side interact with lane
    // chunk boundaries.
    let f = Frame::test_card(18, 11); // 18 = LANES + 2: border in chunk 2
    let plan = plan_for(FilterKind::Conv5x5, OpMode::Exact);
    let scalar = run(&plan, ExecPlan::Scalar, &f);
    let batched = run(&plan, ExecPlan::Batched, &f);
    assert_bit_identical(&scalar, &batched, "conv5x5 w=18");
}

#[test]
fn tiled_sessions_bit_identical_for_every_filter() {
    let f = Frame::test_card(45, 23);
    for kind in FilterKind::NETLIST {
        let plan = plan_for(kind, OpMode::Exact);
        let want = plan.run_frame_sequential(&f);
        for workers in [1usize, 3, 4] {
            let got = run(&plan, ExecPlan::Tiled { workers }, &f);
            assert_bit_identical(
                &got,
                &want,
                &format!("{} workers={workers}", kind.name()),
            );
        }
    }
}

#[test]
fn tiled_more_workers_than_rows() {
    let f = Frame::gradient(20, 5);
    let plan = plan_for(FilterKind::Median, OpMode::Exact);
    let want = plan.run_frame_sequential(&f);
    let got = run(&plan, ExecPlan::Tiled { workers: 32 }, &f);
    assert_bit_identical(&got, &want, "workers>rows");
}

#[test]
fn streaming_session_bit_identical_to_oracle() {
    let plan = plan_for(FilterKind::FpSobel, OpMode::Exact);
    let frames: Vec<Frame> = (0..5).map(|i| Frame::noise(29, 13, i)).collect();
    let mut session = plan.session(ExecPlan::Streaming { workers: 3, reorder: 4 }).unwrap();
    let mut outs = Vec::new();
    let m = session.process_sequence(frames.clone(), |_, f| outs.push(f)).unwrap();
    assert_eq!(m.frames, 5);
    assert!(m.p99_latency <= m.max_latency);
    for (f, got) in frames.iter().zip(&outs) {
        assert_bit_identical(got, &plan.run_frame_sequential(f), "pipeline frame");
    }
}

/// The tentpole parity claim: every canonical DSL program is bitwise
/// identical to the built-in netlist it mirrors through every execution
/// plan, in both numeric modes.
#[test]
fn dsl_programs_bit_identical_to_builtins_all_plans_both_modes() {
    // 37 = 2·LANES + 5 ragged tail; salt-and-pepper hits the CAS/minmax
    // datapaths with extremes.
    let frames = [
        Frame::test_card(37, 19),
        Frame::salt_pepper(37, 19, 0.15, 11),
    ];
    for (kind, src) in DSL_SUITE {
        for mode in [OpMode::Exact, OpMode::Poly] {
            let builtin = plan_for(kind, mode);
            let dsl =
                Pipeline::new().dsl_named(src, kind.name()).compile(mode).unwrap();
            let (bhw, dhw) = (&builtin.stages()[0], &dsl.stages()[0]);
            assert_eq!(dhw.fmt, bhw.fmt, "{}", kind.name());
            assert_eq!(dhw.ksize, bhw.ksize, "{}", kind.name());
            assert_eq!(dsl.datapath_latency(), builtin.datapath_latency(), "{}", kind.name());
            for (i, f) in frames.iter().enumerate() {
                let want = builtin.run_frame_sequential(f);
                for exec in [
                    ExecPlan::Scalar,
                    ExecPlan::Batched,
                    ExecPlan::Tiled { workers: 3 },
                    ExecPlan::streaming(2),
                ] {
                    let got = run(&dsl, exec, f);
                    assert_bit_identical(
                        &got,
                        &want,
                        &format!("dsl {} {mode:?} frame{i} {exec}", kind.name()),
                    );
                }
            }
        }
    }
}

/// A long-lived DSL-filter session streams a whole sequence unchanged.
#[test]
fn dsl_filter_through_streaming_session() {
    let (kind, src) = (FilterKind::Nlfilter, DSL_SUITE[3].1);
    let builtin = plan_for(kind, OpMode::Exact);
    let dsl = Pipeline::new().dsl_named(src, "nlfilter_dsl").compile(OpMode::Exact).unwrap();
    let frames: Vec<Frame> = (0..6).map(|i| Frame::noise(33, 14, 100 + i)).collect();
    let mut session = dsl.session(ExecPlan::streaming(3)).unwrap();
    let mut outs = Vec::new();
    let m = session.process_sequence(frames.clone(), |_, f| outs.push(f)).unwrap();
    assert_eq!(m.frames, 6);
    for (f, got) in frames.iter().zip(&outs) {
        assert_bit_identical(got, &builtin.run_frame_sequential(f), "dsl pipeline frame");
    }
}
