//! Chaos suite (`cargo test --features fault-injection`): every recovery
//! path of the supervised session runtime, driven by deterministic
//! [`FaultScript`]s.
//!
//! The contract under test (ROADMAP "supervised runtime"):
//!
//! * an injected worker panic at frame *k* surfaces as a typed
//!   [`ExecError::WorkerPanicked`] identifying frame *k*, the worker is
//!   respawned, and frames *k+1..n* are **bit-identical** to the
//!   sequential oracle;
//! * a `DropNewest`/`DropOldest` session under overload reports *exact*
//!   drop counts in [`Metrics`], and the surviving outputs stay strictly
//!   in submission order, oracle-identical;
//! * deadline misses are typed, counted, and never poison the session;
//! * corrupt (non-finite) pixels are caught at submission as
//!   [`ExecError::PoisonFrame`];
//! * `Session::reset()` after any fault yields a fully usable session.

#![cfg(feature = "fault-injection")]

use std::sync::Arc;
use std::time::Duration;

use fpspatial::filters::FilterKind;
use fpspatial::fpcore::{FloatFormat, OpMode};
use fpspatial::pipeline::{
    CompiledPipeline, ExecError, ExecPlan, FrameServer, OverloadPolicy, Pipeline, ServerEvent,
    SessionConfig,
};
use fpspatial::runtime::fault::FaultScript;
use fpspatial::video::Frame;

const F16: FloatFormat = FloatFormat::new(10, 5);
const W: usize = 33;
const H: usize = 21;

const EXECS: [ExecPlan; 4] = [
    ExecPlan::Scalar,
    ExecPlan::Batched,
    ExecPlan::Tiled { workers: 2 },
    ExecPlan::Streaming { workers: 2, reorder: 2 },
];

fn median_plan() -> CompiledPipeline {
    Pipeline::new().builtin(FilterKind::Median).format(F16).compile(OpMode::Exact).unwrap()
}

fn chain_plan() -> CompiledPipeline {
    Pipeline::new()
        .builtin(FilterKind::Median)
        .format(F16)
        .builtin(FilterKind::FpSobel)
        .format(F16)
        .compile(OpMode::Exact)
        .unwrap()
}

fn frames(n: u64) -> Vec<Frame> {
    (0..n).map(|i| Frame::noise(W, H, i)).collect()
}

fn assert_bit_identical(a: &Frame, b: &Frame, what: &str) {
    assert_eq!((a.width, a.height), (b.width, b.height), "{what}: shape");
    for (i, (x, y)) in a.data.iter().zip(&b.data).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: pixel {i}: {x} vs {y}");
    }
}

/// The headline contract: a panic injected at frame k yields a typed
/// `WorkerPanicked` naming frame k, and every subsequent frame is
/// bit-identical to the sequential oracle — under EVERY execution plan.
#[test]
fn injected_panic_is_typed_and_subsequent_frames_match_the_oracle() {
    const K: u64 = 3;
    const N: u64 = 8;
    let plan = median_plan();
    for exec in EXECS {
        let script = Arc::new(FaultScript::new().panic_at(K, "chaos monkey"));
        let cfg = SessionConfig::new().with_faults(script.clone());
        let mut session = plan.session_with(exec, cfg).unwrap();
        for (i, f) in frames(N).iter().enumerate() {
            let i = i as u64;
            if i == K {
                let err = session.process(f).unwrap_err();
                match err.downcast_ref::<ExecError>() {
                    Some(ExecError::WorkerPanicked { frame_seq, payload, .. }) => {
                        assert_eq!(*frame_seq, K, "{exec}");
                        assert!(payload.contains("chaos monkey"), "{exec}: {payload}");
                    }
                    other => panic!("{exec}: expected WorkerPanicked, got {other:?}"),
                }
            } else {
                let got = session.process(f).unwrap();
                assert_bit_identical(
                    &got,
                    &plan.run_frame_sequential(f),
                    &format!("{exec} frame {i}"),
                );
            }
        }
        assert_eq!(script.armed(), 0, "{exec}: the fault never fired");
        assert_eq!(session.worker_restarts(), 1, "{exec}");
        assert_eq!(session.dropped(), 0, "{exec}");
    }
}

/// Same contract on a fused multi-stage chain (the `ChainRunner` worker
/// path rather than the single-stage fast path).
#[test]
fn panic_recovery_on_a_fused_chain() {
    let plan = chain_plan();
    let script = Arc::new(FaultScript::new().panic_at(1, "mid-chain"));
    let cfg = SessionConfig::new().with_faults(script.clone());
    let mut session = plan.session_with(ExecPlan::streaming(2), cfg).unwrap();
    let seq = frames(5);
    assert!(session.process(&seq[0]).is_ok());
    let err = session.process(&seq[1]).unwrap_err();
    assert!(
        matches!(
            err.downcast_ref::<ExecError>(),
            Some(ExecError::WorkerPanicked { frame_seq: 1, .. })
        ),
        "{err}"
    );
    for f in &seq[2..] {
        let got = session.process(f).unwrap();
        assert_bit_identical(&got, &plan.run_frame_sequential(f), "post-panic chain frame");
    }
    assert_eq!(script.armed(), 0);
    assert_eq!(session.worker_restarts(), 1);
}

/// A panic during `process_sequence` aborts the sequence with the typed
/// error (the bulk path stays loud), but the session itself survives and
/// keeps producing oracle-identical output.
#[test]
fn sequence_reports_the_panic_and_the_session_survives() {
    let plan = median_plan();
    let script = Arc::new(FaultScript::new().panic_at(2, "boom"));
    let cfg = SessionConfig::new().with_faults(script.clone());
    let mut session = plan.session_with(ExecPlan::streaming(2), cfg).unwrap();
    let err = session.process_sequence(frames(6), |_, _| {}).unwrap_err();
    match err.downcast_ref::<ExecError>() {
        Some(ExecError::WorkerPanicked { frame_seq: 2, payload, .. }) => {
            assert!(payload.contains("boom"), "{payload}");
        }
        other => panic!("expected WorkerPanicked at frame 2, got {other:?}"),
    }
    assert_eq!(session.worker_restarts(), 1);
    let probe = Frame::noise(W, H, 99);
    let got = session.process(&probe).unwrap();
    assert_bit_identical(&got, &plan.run_frame_sequential(&probe), "post-sequence-panic");
}

/// DropNewest under sustained overload (every worker slowed far beyond
/// the submission rate): the submitter never waits on a blocking poll,
/// `Metrics` reports the exact drop count, and the surviving outputs are
/// in-order and oracle-identical.
#[test]
fn drop_newest_counts_exactly_and_keeps_order() {
    const N: u64 = 12;
    let plan = median_plan();
    let mut script = FaultScript::new();
    for i in 0..N {
        script = script.delay_at(i, Duration::from_millis(25));
    }
    let cfg = SessionConfig::new()
        .overload(OverloadPolicy::DropNewest)
        .with_faults(Arc::new(script));
    let mut session = plan
        .session_with(ExecPlan::Streaming { workers: 2, reorder: 1 }, cfg)
        .unwrap();
    let input = frames(N);
    let mut delivered: Vec<(u64, Frame)> = Vec::new();
    let m = session.process_sequence(input.clone(), |seq, f| delivered.push((seq, f))).unwrap();
    assert_eq!(m.frames, N);
    // exact accounting: every submitted frame was either delivered or
    // counted as dropped — nothing lost, nothing double-counted
    assert_eq!(delivered.len() as u64 + m.dropped, N, "dropped {}", m.dropped);
    // 2 workers sleeping 25ms against an instantaneous submitter with an
    // in-flight budget of 3 MUST shed load
    assert!(m.dropped > 0, "overload produced no drops");
    assert!(m.worker_restarts == 0 && m.deadline_misses == 0);
    // survivors are strictly ascending and bit-identical to the oracle
    // of the frame that was actually submitted under that index
    for w in delivered.windows(2) {
        assert!(w[0].0 < w[1].0, "out of order: {} then {}", w[0].0, w[1].0);
    }
    for (seq, out) in &delivered {
        let want = plan.run_frame_sequential(&input[*seq as usize]);
        assert_bit_identical(out, &want, &format!("dropped-run frame {seq}"));
    }
    // the wall clock beats a fully serial drain of all N delays: the
    // submitter was shedding, not blocking
    assert!(
        m.elapsed < Duration::from_millis(25 * N as u64),
        "submitter appears to have blocked: {:?}",
        m.elapsed
    );
}

/// DropOldest retracts queued-but-unclaimed frames so the freshest data
/// wins; accounting and ordering hold just like DropNewest.
#[test]
fn drop_oldest_retracts_queued_frames() {
    const N: u64 = 10;
    let plan = median_plan();
    let mut script = FaultScript::new();
    for i in 0..N {
        script = script.delay_at(i, Duration::from_millis(20));
    }
    let cfg = SessionConfig::new()
        .overload(OverloadPolicy::DropOldest)
        .with_faults(Arc::new(script));
    let mut session = plan
        .session_with(ExecPlan::Streaming { workers: 1, reorder: 2 }, cfg)
        .unwrap();
    let input = frames(N);
    let mut delivered: Vec<(u64, Frame)> = Vec::new();
    let m = session.process_sequence(input.clone(), |seq, f| delivered.push((seq, f))).unwrap();
    assert_eq!(delivered.len() as u64 + m.dropped, N);
    assert!(m.dropped > 0, "overload produced no drops");
    for w in delivered.windows(2) {
        assert!(w[0].0 < w[1].0, "out of order");
    }
    for (seq, out) in &delivered {
        let want = plan.run_frame_sequential(&input[*seq as usize]);
        assert_bit_identical(out, &want, &format!("retracted-run frame {seq}"));
    }
    // freshest-data-wins: the LAST submitted frame is never the one
    // retracted, so the tail of the sequence survives
    assert_eq!(delivered.last().unwrap().0, N - 1, "the freshest frame was lost");
}

/// Blocking backpressure bounded by a deadline: a budget that stays full
/// for a whole deadline surfaces as a typed `QueueOverflow` naming the
/// frame that could not be submitted, and the session recovers.
#[test]
fn blocked_submission_times_out_as_queue_overflow() {
    let plan = median_plan();
    let script = FaultScript::new()
        .delay_at(0, Duration::from_millis(400))
        .delay_at(1, Duration::from_millis(400));
    let cfg = SessionConfig::new()
        .deadline(Duration::from_millis(80))
        .with_faults(Arc::new(script));
    let mut session = plan
        .session_with(ExecPlan::Streaming { workers: 1, reorder: 1 }, cfg)
        .unwrap();
    let err = session.process_sequence(frames(4), |_, _| {}).unwrap_err();
    match err.downcast_ref::<ExecError>() {
        Some(ExecError::QueueOverflow { frame_seq: 2, capacity: 2, .. }) => {}
        other => panic!("expected QueueOverflow at frame 2, got {other:?}"),
    }
    // let the slowed worker drain its stale frame, then reuse the session
    std::thread::sleep(Duration::from_millis(900));
    let probe = Frame::noise(W, H, 7);
    let got = session.process(&probe).unwrap();
    assert_bit_identical(&got, &plan.run_frame_sequential(&probe), "post-overflow");
}

/// Per-frame deadlines on the streaming path: the slowed frame comes
/// back as a typed `DeadlineExceeded`, is counted as both a miss and a
/// drop, and the next frame (after the worker wakes) is served normally.
#[test]
fn deadline_miss_is_typed_counted_and_isolated() {
    let plan = median_plan();
    let script = Arc::new(FaultScript::new().delay_at(1, Duration::from_millis(600)));
    let cfg = SessionConfig::new()
        .deadline(Duration::from_millis(150))
        .with_faults(script.clone());
    let mut session = plan.session_with(ExecPlan::streaming(1), cfg).unwrap();
    let seq = frames(3);
    assert!(session.process(&seq[0]).is_ok(), "an unslowed frame beats a 150ms deadline");
    let err = session.process(&seq[1]).unwrap_err();
    match err.downcast_ref::<ExecError>() {
        Some(ExecError::DeadlineExceeded { frame_seq: 1, deadline, elapsed }) => {
            assert_eq!(*deadline, Duration::from_millis(150));
            assert!(*elapsed >= *deadline, "{elapsed:?}");
        }
        other => panic!("expected DeadlineExceeded at frame 1, got {other:?}"),
    }
    assert_eq!(session.deadline_misses(), 1);
    assert_eq!(session.dropped(), 1);
    // wait out the injected latency so the worker is idle again
    std::thread::sleep(Duration::from_millis(700));
    let got = session.process(&seq[2]).unwrap();
    assert_bit_identical(&got, &plan.run_frame_sequential(&seq[2]), "post-deadline-miss");
    assert_eq!(script.armed(), 0);
}

/// Serial plans cannot be preempted, so a blown deadline still delivers
/// the frame — but it is counted as a miss.
#[test]
fn direct_plans_count_post_hoc_deadline_misses() {
    let plan = median_plan();
    for exec in [ExecPlan::Batched, ExecPlan::Tiled { workers: 2 }] {
        let script = Arc::new(FaultScript::new().delay_at(0, Duration::from_millis(60)));
        let cfg = SessionConfig::new().deadline(Duration::from_millis(5)).with_faults(script);
        let mut session = plan.session_with(exec, cfg).unwrap();
        let f = Frame::noise(W, H, 0);
        let got = session.process(&f).unwrap();
        assert_bit_identical(&got, &plan.run_frame_sequential(&f), &format!("{exec}"));
        assert_eq!(session.deadline_misses(), 1, "{exec}");
        assert_eq!(session.dropped(), 0, "{exec}");
    }
}

/// Injected pixel corruption is caught by submission screening as a
/// typed `PoisonFrame` — proving validation guards the real datapaths.
#[test]
fn injected_corruption_is_rejected_as_poison() {
    let plan = median_plan();
    for exec in [ExecPlan::Batched, ExecPlan::streaming(2)] {
        let script = Arc::new(FaultScript::new().corrupt_at(2, f64::NEG_INFINITY));
        let cfg = SessionConfig::new().with_faults(script.clone());
        let mut session = plan.session_with(exec, cfg).unwrap();
        for (i, f) in frames(4).iter().enumerate() {
            // the corruption hook consumes sequence slot 2's entry the
            // first time slot 2 is screened
            let r = session.process(f);
            if script.armed() == 0 && r.is_err() {
                let err = r.unwrap_err();
                assert!(
                    matches!(
                        err.downcast_ref::<ExecError>(),
                        Some(ExecError::PoisonFrame { index: 0, .. })
                    ),
                    "{exec} frame {i}: {err}"
                );
            } else {
                let got = r.unwrap();
                assert_bit_identical(
                    &got,
                    &plan.run_frame_sequential(f),
                    &format!("{exec} frame {i}"),
                );
            }
        }
        assert_eq!(script.armed(), 0, "{exec}: the corruption never fired");
    }
}

/// `Session::reset()` after a contained fault: the session accepts a new
/// geometry and produces oracle-identical output.
#[test]
fn reset_after_fault_accepts_a_new_geometry() {
    let plan = median_plan();
    let script = Arc::new(FaultScript::new().panic_at(0, "first frame dies"));
    let cfg = SessionConfig::new().with_faults(script);
    let mut session = plan.session_with(ExecPlan::streaming(2), cfg).unwrap();
    let err = session.process(&Frame::noise(W, H, 0)).unwrap_err();
    assert!(err.to_string().contains("first frame dies"), "{err}");
    session.reset();
    let probe = Frame::test_card(48, 30);
    let got = session.process(&probe).unwrap();
    assert_bit_identical(&got, &plan.run_frame_sequential(&probe), "post-reset new geometry");
    assert_eq!(session.worker_restarts(), 1);
}

/// Two faults on one session: the supervisor respawns workers each time
/// and the counters accumulate across recoveries.
#[test]
fn repeated_panics_respawn_repeatedly() {
    let plan = median_plan();
    let script = Arc::new(FaultScript::new().panic_at(1, "first").panic_at(3, "second"));
    let cfg = SessionConfig::new().with_faults(script.clone());
    let mut session = plan.session_with(ExecPlan::streaming(2), cfg).unwrap();
    let seq = frames(6);
    let mut failures = 0;
    for (i, f) in seq.iter().enumerate() {
        match session.process(f) {
            Ok(got) => assert_bit_identical(
                &got,
                &plan.run_frame_sequential(f),
                &format!("frame {i}"),
            ),
            Err(e) => {
                assert!(
                    matches!(
                        e.downcast_ref::<ExecError>(),
                        Some(ExecError::WorkerPanicked { .. })
                    ),
                    "frame {i}: {e}"
                );
                failures += 1;
            }
        }
    }
    assert_eq!(failures, 2);
    assert_eq!(session.worker_restarts(), 2);
    assert_eq!(script.armed(), 0);
}

/// The poisoned-lock fix: a panic injected *inside the dequeue critical
/// section* — job-queue mutex held, job not yet claimed — poisons the
/// mutex on purpose.  The pool must recover the guard instead of
/// unwrapping it, leave the frame queued for a healthy peer, respawn
/// the casualty, and deliver EVERY frame bit-identically (the dying
/// worker never claimed one).
#[test]
fn worker_panic_mid_dequeue_poisons_the_lock_and_the_pool_keeps_serving() {
    const N: u64 = 8;
    let plan = median_plan();
    let script = Arc::new(FaultScript::new().panic_at_dequeue(2, "lock poisoner"));
    let cfg = SessionConfig::new().with_faults(script.clone());
    let mut session = plan.session_with(ExecPlan::streaming(2), cfg).unwrap();
    let input = frames(N);
    let mut delivered: Vec<(u64, Frame)> = Vec::new();
    let m = session.process_sequence(input.clone(), |seq, f| delivered.push((seq, f))).unwrap();
    assert_eq!(delivered.len() as u64, N, "every frame survives the poisoned lock");
    for (seq, out) in &delivered {
        let want = plan.run_frame_sequential(&input[*seq as usize]);
        assert_bit_identical(out, &want, &format!("post-poison frame {seq}"));
    }
    assert_eq!(m.worker_restarts, 1, "the dequeue casualty was respawned");
    assert_eq!((m.dropped, m.deadline_misses), (0, 0));
    assert_eq!(script.armed(), 0, "the dequeue fault never fired");
}

/// Fault isolation across the shared pool: stream 1 of a two-stream
/// [`FrameServer`] carries a chaos script that kills a worker mid-job.
/// Stream 0 must come out complete, in order and oracle-identical with
/// all-zero counters — even though the panicked worker also served its
/// frames — while stream 1 reports the typed fault, skips exactly that
/// frame, and books exactly one restart.
#[test]
fn server_panic_on_one_stream_never_touches_the_other() {
    const F: usize = 6;
    const K: u64 = 2;
    let plan = median_plan();
    let script = Arc::new(FaultScript::new().panic_at(K, "stream-1 chaos"));
    let mut server = FrameServer::builder(2)
        .stream(&plan, SessionConfig::new())
        .stream(&plan, SessionConfig::new().with_faults(script.clone()))
        .build()
        .unwrap();
    let inputs: Vec<Frame> = (0..F).map(|i| Frame::noise(W, H, i as u64)).collect();
    for f in &inputs {
        server.submit(0, f).unwrap();
        server.submit(1, f).unwrap();
    }
    let mut got: Vec<Vec<(u64, Frame)>> = vec![Vec::new(); 2];
    let mut faults: Vec<(usize, ExecError)> = Vec::new();
    for ev in server.drain().unwrap() {
        match ev {
            ServerEvent::Frame { stream, seq, frame, .. } => got[stream].push((seq, frame)),
            ServerEvent::Fault { stream, error } => faults.push((stream, error)),
        }
    }

    assert_eq!(got[0].len(), F, "stream 0 lost nothing");
    for (i, (seq, frame)) in got[0].iter().enumerate() {
        assert_eq!(*seq, i as u64, "stream 0 in order");
        assert_bit_identical(frame, &plan.run_frame_sequential(&inputs[i]), "stream 0");
    }
    let m0 = server.metrics(0);
    assert_eq!((m0.dropped, m0.deadline_misses, m0.worker_restarts), (0, 0, 0));

    assert_eq!(faults.len(), 1, "exactly one fault event");
    match &faults[0] {
        (1, ExecError::WorkerPanicked { frame_seq, payload, .. }) => {
            assert_eq!(*frame_seq, K);
            assert!(payload.contains("stream-1 chaos"), "{payload}");
        }
        other => panic!("expected a stream-1 WorkerPanicked, got {other:?}"),
    }
    assert_eq!(got[1].len(), F - 1, "stream 1 skipped exactly the panicked frame");
    for (seq, frame) in &got[1] {
        assert_ne!(*seq, K, "the panicked frame was never delivered");
        let want = plan.run_frame_sequential(&inputs[*seq as usize]);
        assert_bit_identical(frame, &want, &format!("stream 1 frame {seq}"));
    }
    let m1 = server.metrics(1);
    assert_eq!(m1.worker_restarts, 1, "the casualty was respawned, once");
    assert_eq!(m1.delivered, (F - 1) as u64);
    assert_eq!(script.armed(), 0);
}
