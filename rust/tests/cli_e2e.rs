//! End-to-end CLI tests: drive `Args::parse` + dispatch in-process
//! (`fpspatial::cli::run`) for every program in `examples/dsl/`, and
//! assert the error paths are usable diagnostics, not panics.

use std::path::{Path, PathBuf};

use fpspatial::cli;

fn sv(args: &[&str]) -> Vec<String> {
    args.iter().map(|s| s.to_string()).collect()
}

fn dsl_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("../examples/dsl")
}

/// Every committed example program, with whether it declares a
/// sliding_window (fig12 is the scalar z = sqrt(xy/(x+y)) program).
fn example_programs() -> Vec<(PathBuf, bool)> {
    let mut out: Vec<(PathBuf, bool)> = std::fs::read_dir(dsl_dir())
        .expect("examples/dsl exists")
        .filter_map(|e| {
            let p = e.ok()?.path();
            if p.extension().and_then(|x| x.to_str()) == Some("dsl") {
                let src = std::fs::read_to_string(&p).ok()?;
                Some((p, src.contains("sliding_window")))
            } else {
                None
            }
        })
        .collect();
    out.sort();
    assert!(out.len() >= 6, "expected the committed DSL suite, got {out:?}");
    out
}

fn tmp_path(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("fpspatial_cli_e2e_{}_{name}", std::process::id()))
}

#[test]
fn compile_succeeds_for_every_example_program() {
    for (p, _) in example_programs() {
        let out = tmp_path(&format!(
            "{}.sv",
            p.file_stem().unwrap().to_str().unwrap()
        ));
        let res = cli::run(&sv(&[
            "compile",
            p.to_str().unwrap(),
            "-o",
            out.to_str().unwrap(),
            "--report",
        ]));
        assert!(res.is_ok(), "compile {p:?}: {:#}", res.unwrap_err());
        assert!(out.exists(), "no output for {p:?}");
        let _ = std::fs::remove_file(out);
    }
}

#[test]
fn run_succeeds_for_every_window_program() {
    for (p, windowed) in example_programs() {
        let res = cli::run(&sv(&["run", "--dsl", p.to_str().unwrap(), "--size", "24x16"]));
        if windowed {
            assert!(res.is_ok(), "run {p:?}: {:#}", res.unwrap_err());
        } else {
            // scalar programs are a usable error, not a panic
            let err = format!("{:#}", res.unwrap_err());
            assert!(err.contains("sliding_window"), "run {p:?}: {err}");
        }
    }
}

#[test]
fn batched_run_succeeds_for_every_window_program() {
    for (p, windowed) in example_programs() {
        if !windowed {
            continue;
        }
        let res = cli::run(&sv(&[
            "run",
            "--dsl",
            p.to_str().unwrap(),
            "--size",
            "33x16",
            "--batched",
            "--mode",
            "poly",
        ]));
        assert!(res.is_ok(), "run --batched {p:?}: {:#}", res.unwrap_err());
    }
}

#[test]
fn pipeline_succeeds_for_every_window_program() {
    for (p, windowed) in example_programs() {
        if !windowed {
            continue;
        }
        let res = cli::run(&sv(&[
            "pipeline",
            "--dsl",
            p.to_str().unwrap(),
            "--frames",
            "2",
            "--workers",
            "2",
            "--size",
            "24x16",
        ]));
        assert!(res.is_ok(), "pipeline {p:?}: {:#}", res.unwrap_err());
    }
}

/// The acceptance-criterion invocation: a fused two-DSL chain end to end
/// with chain-wide latency and resource reporting.
#[test]
fn chain_pipeline_end_to_end() {
    let med = dsl_dir().join("median.dsl");
    let sob = dsl_dir().join("sobel.dsl");
    let res = cli::run(&sv(&[
        "pipeline",
        "--dsl",
        med.to_str().unwrap(),
        "--dsl",
        sob.to_str().unwrap(),
        "--frames",
        "2",
        "--workers",
        "2",
        "--size",
        "32x24",
        "--batched",
    ]));
    assert!(res.is_ok(), "{:#}", res.unwrap_err());
}

#[test]
fn chain_run_mixes_builtin_and_dsl_stages() {
    let sob = dsl_dir().join("sobel.dsl");
    let res = cli::run(&sv(&[
        "run",
        "--filter",
        "median",
        "--dsl",
        sob.to_str().unwrap(),
        "--size",
        "32x24",
    ]));
    assert!(res.is_ok(), "{:#}", res.unwrap_err());
}

#[test]
fn compile_emit_netlist_writes_json() {
    let p = dsl_dir().join("nlfilter.dsl");
    let out = tmp_path("nlfilter.netlist.json");
    let res = cli::run(&sv(&[
        "compile",
        p.to_str().unwrap(),
        "--emit",
        "netlist",
        "-o",
        out.to_str().unwrap(),
    ]));
    assert!(res.is_ok(), "{:#}", res.unwrap_err());
    let txt = std::fs::read_to_string(&out).unwrap();
    let v = fpspatial::util::json::Json::parse(&txt).unwrap();
    assert_eq!(v.get("name").unwrap().as_str(), Some("nlfilter"));
    assert_eq!(
        v.get("netlist").unwrap().get("total_latency").unwrap().as_usize(),
        Some(26)
    );
    assert!(v.get("window").unwrap().get("height").unwrap().as_usize() == Some(3));
    let _ = std::fs::remove_file(out);
}

#[test]
fn compile_mixed_format_cascade_emits_sv_and_netlist() {
    let out = tmp_path("cascade.sv");
    let res = cli::run(&sv(&[
        "compile", "--filter", "median", "--fmt", "10,5", "--filter", "fp_sobel",
        "--fmt", "7,6", "--emit", "sv", "-o", out.to_str().unwrap(), "--report",
    ]));
    assert!(res.is_ok(), "{:#}", res.unwrap_err());
    let text = std::fs::read_to_string(&out).unwrap();
    assert!(text.contains("fmt_converter #("), "no converter in:\n{text}");
    assert_eq!(text.matches("endmodule").count(), 3);
    let _ = std::fs::remove_file(out);

    let outj = tmp_path("cascade.netlist.json");
    let res = cli::run(&sv(&[
        "compile", "--filter", "median", "--fmt", "10,5", "--filter", "fp_sobel",
        "--fmt", "7,6", "--emit", "netlist", "-o", outj.to_str().unwrap(),
    ]));
    assert!(res.is_ok(), "{:#}", res.unwrap_err());
    let v = fpspatial::util::json::Json::parse(&std::fs::read_to_string(&outj).unwrap()).unwrap();
    assert_eq!(v.get("stages").unwrap().as_arr().unwrap().len(), 2);
    assert_eq!(v.get("converters").unwrap().as_arr().unwrap().len(), 1);
    let _ = std::fs::remove_file(outj);
}

#[test]
fn mixed_format_chain_runs_end_to_end() {
    let res = cli::run(&sv(&[
        "run", "--filter", "median", "--fmt", "16,7", "--filter", "fp_sobel",
        "--fmt", "10,5", "--size", "32x24", "--batched",
    ]));
    assert!(res.is_ok(), "{:#}", res.unwrap_err());
}

/// The unified `--exec` flag drives every execution plan end to end, on
/// both the `run` and `pipeline` commands.
#[test]
fn exec_flag_runs_every_plan() {
    for exec in ["scalar", "batched", "tiled:2", "streaming:2"] {
        let res = cli::run(&sv(&["run", "median", "--size", "24x16", "--exec", exec]));
        assert!(res.is_ok(), "run --exec {exec}: {:#}", res.unwrap_err());
    }
    let res = cli::run(&sv(&[
        "pipeline", "--filter", "median", "--frames", "2", "--size", "24x16", "--exec",
        "tiled:2",
    ]));
    assert!(res.is_ok(), "pipeline --exec tiled:2: {:#}", res.unwrap_err());
    // chains take --exec too
    let sob = dsl_dir().join("sobel.dsl");
    let res = cli::run(&sv(&[
        "run", "--filter", "median", "--dsl", sob.to_str().unwrap(), "--size", "32x24",
        "--exec", "streaming:2",
    ]));
    assert!(res.is_ok(), "{:#}", res.unwrap_err());
}

/// Malformed `--exec` specs are parse-rejected with usable diagnostics.
#[test]
fn malformed_exec_specs_are_usable_errors() {
    for (spec, needle) in [
        ("warp", "warp"),
        ("tiled", "worker count"),
        ("streaming", "worker count"),
        ("tiled:0", "at least one"),
        ("streaming:0", "at least one"),
        ("tiled:abc", "integer"),
        ("scalar:2", "no worker"),
        ("batched:4", "no worker"),
    ] {
        let err =
            cli::run(&sv(&["run", "median", "--size", "24x16", "--exec", spec])).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains(needle), "--exec {spec}: {msg}");
    }
    // --exec and the legacy --batched alias conflict loudly
    let err = cli::run(&sv(&[
        "run", "median", "--size", "24x16", "--exec", "batched", "--batched",
    ]))
    .unwrap_err();
    assert!(format!("{err:#}").contains("mutually exclusive"), "{err:#}");
    // ... and so do --workers and an explicit --exec (the plan carries
    // its own worker count); the error suggests the right spelling
    let err = cli::run(&sv(&[
        "pipeline", "--filter", "median", "--frames", "2", "--size", "24x16",
        "--workers", "8", "--exec", "streaming:2",
    ]))
    .unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("mutually exclusive"), "{msg}");
    assert!(msg.contains("streaming:8"), "{msg}");
}

/// CNN-shaped stage flags end to end: a strided conv feeding a 2×2 pool
/// (`--stride`/`--pool` bind to the preceding stage like `--fmt`), under
/// an explicit execution plan.
#[test]
fn strided_and_pooled_stages_run_end_to_end() {
    let res = cli::run(&sv(&[
        "run", "--filter", "conv3x3", "--stride", "2", "--pool", "2,2", "--size",
        "33x24", "--exec", "tiled:2",
    ]));
    assert!(res.is_ok(), "{:#}", res.unwrap_err());
    // the same shape through the pipeline command's streaming plan
    let res = cli::run(&sv(&[
        "pipeline", "--filter", "conv3x3", "--pool", "3,2", "--frames", "2",
        "--workers", "2", "--size", "32x24",
    ]));
    assert!(res.is_ok(), "{:#}", res.unwrap_err());
    // a zero stride parses but is rejected at compile with the geometry
    // error, not a panic
    let err = cli::run(&sv(&[
        "run", "--filter", "conv3x3", "--stride", "0", "--size", "24x16",
    ]))
    .unwrap_err();
    assert!(format!("{err:#}").contains("stride"), "{err:#}");
}

fn net_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("../examples/net")
}

/// The checked-in VGG-style descriptor streams end to end through the
/// pipeline command (the CI invocation).
#[test]
fn net_descriptor_pipeline_end_to_end() {
    let net = net_dir().join("vgg_block.net");
    let res = cli::run(&sv(&[
        "pipeline",
        "--net",
        net.to_str().unwrap(),
        "--frames",
        "2",
        "--workers",
        "2",
        "--size",
        "32x24",
    ]));
    assert!(res.is_ok(), "{:#}", res.unwrap_err());
    // --net and stage flags conflict loudly
    let err = cli::run(&sv(&[
        "pipeline", "--net", net.to_str().unwrap(), "--filter", "median", "--frames",
        "1", "--size", "24x16",
    ]))
    .unwrap_err();
    assert!(format!("{err:#}").contains("--net"), "{err:#}");
    // a missing descriptor is a usable error naming the path
    let err = cli::run(&sv(&[
        "pipeline", "--net", "/no/such/stack.net", "--frames", "1",
    ]))
    .unwrap_err();
    assert!(format!("{err:#}").contains("/no/such/stack.net"), "{err:#}");
}

#[test]
fn bad_fmt_and_bad_emit_are_usable_errors() {
    let err = cli::run(&sv(&[
        "run", "--filter", "median", "--fmt", "bogus", "--size", "16x12",
    ]))
    .unwrap_err();
    assert!(format!("{err:#}").contains("bogus"), "{err:#}");

    let p = dsl_dir().join("median.dsl");
    let err = cli::run(&sv(&[
        "compile", p.to_str().unwrap(), "--emit", "verilog2001",
    ]))
    .unwrap_err();
    assert!(format!("{err:#}").contains("verilog2001"), "{err:#}");
}

#[test]
fn missing_file_is_a_usable_error() {
    let err = cli::run(&sv(&["run", "--dsl", "/no/such/program.dsl"])).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("/no/such/program.dsl"), "{msg}");
}

#[test]
fn bad_program_is_a_usable_error() {
    let p = tmp_path("bad.dsl");
    std::fs::write(&p, "use float(10,5);\nz = sqrt(").unwrap();
    let err = cli::run(&sv(&["run", "--dsl", p.to_str().unwrap()])).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("compiling"), "{msg}");
    let _ = std::fs::remove_file(p);
}

#[test]
fn conflicting_filter_selections_are_a_usable_error() {
    let med = dsl_dir().join("median.dsl");
    let err =
        cli::run(&sv(&["run", "median", "--dsl", med.to_str().unwrap()])).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("pick one"), "{msg}");
}

#[test]
fn frame_narrower_than_the_window_is_a_usable_error() {
    let err = cli::run(&sv(&["run", "conv5x5", "--size", "4x8"])).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("narrower"), "{msg}");

    // chains report the offending stage by name
    let med = dsl_dir().join("median.dsl");
    let err = cli::run(&sv(&[
        "pipeline",
        "--dsl",
        med.to_str().unwrap(),
        "--filter",
        "conv5x5",
        "--frames",
        "1",
        "--size",
        "4x8",
    ]))
    .unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("conv5x5"), "{msg}");
}

#[test]
fn hls_sobel_still_runs_and_chains_reject_it_usably() {
    assert!(cli::run(&sv(&["run", "hls_sobel", "--size", "16x12"])).is_ok());
    let med = dsl_dir().join("median.dsl");
    let err = cli::run(&sv(&[
        "pipeline",
        "--filter",
        "hls_sobel",
        "--dsl",
        med.to_str().unwrap(),
        "--frames",
        "1",
        "--size",
        "16x12",
    ]))
    .unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("hls_sobel"), "{msg}");
}

#[test]
fn unknown_filter_and_mode_are_usable_errors() {
    let err = cli::run(&sv(&["run", "nosuch"])).unwrap_err();
    assert!(format!("{err:#}").contains("unknown filter"), "{err:#}");
    let err = cli::run(&sv(&["run", "median", "--mode", "fuzzy"])).unwrap_err();
    assert!(format!("{err:#}").contains("unknown mode"), "{err:#}");
    let err = cli::run(&sv(&["nosuchcmd"])).unwrap_err();
    assert!(format!("{err:#}").contains("unknown command"), "{err:#}");
}

#[test]
fn help_and_bench_latency_smoke() {
    assert!(cli::run(&sv(&["help"])).is_ok());
    assert!(cli::run(&[]).is_ok());
    assert!(cli::run(&sv(&["bench", "latency"])).is_ok());
}
