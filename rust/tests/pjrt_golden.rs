//! Integration: every golden artifact (5 filters × 5 formats, lowered from
//! JAX/Pallas) must match the Rust cycle simulator **bit-for-bit**.
//!
//! This is the cross-language numerics contract of DESIGN.md §6: the jnp
//! `quantize` emulation and `fpcore::quantize` compute identical roundings
//! (both via IEEE doubles), and every filter uses the same canonical
//! accumulation / CAS order on both sides.
//!
//! Requires `make artifacts` (skipped with a message otherwise) and the
//! `pjrt` cargo feature (the XLA client the offline build does not ship).

#![cfg(feature = "pjrt")]

use fpspatial::filters::{conv, FilterKind, HwFilter};
use fpspatial::fpcore::{quantize, FloatFormat, OpMode};
use fpspatial::pipeline::Pipeline;
use fpspatial::runtime::Runtime;
use fpspatial::video::Frame;

fn runtime() -> Option<Runtime> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    match Runtime::new(&dir) {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("skipping PJRT golden tests: {e:#}");
            None
        }
    }
}

fn simulate(kind: FilterKind, fmt: FloatFormat, frame: &Frame, kernel: Option<&[f64]>) -> Frame {
    let qframe = Frame {
        width: frame.width,
        height: frame.height,
        data: frame.data.iter().map(|&v| quantize(v, fmt)).collect(),
    };
    // the plan's sequential oracle is the simulator-side reference
    let hw = match kind {
        FilterKind::Conv3x3 | FilterKind::Conv5x5 => {
            let kq: Vec<f64> = kernel.unwrap().iter().map(|&v| quantize(v, fmt)).collect();
            HwFilter::with_kernel(kind, fmt, &kq)
        }
        _ => HwFilter::new(kind, fmt).unwrap(),
    };
    Pipeline::from_stages([hw])
        .compile(OpMode::Exact)
        .unwrap()
        .run_frame_sequential(&qframe)
}

/// All 25 golden artifacts, bit-exact.
#[test]
fn all_golden_artifacts_bit_exact() {
    let Some(rt) = runtime() else { return };
    let golden: Vec<_> = rt
        .manifest()
        .iter()
        .filter(|e| e.set == "golden")
        .cloned()
        .collect();
    assert!(golden.len() >= 25, "expected >= 25 golden artifacts, got {}", golden.len());

    let mut checked = 0;
    for entry in &golden {
        let fmt = FloatFormat::new(entry.mantissa.unwrap(), entry.exponent.unwrap());
        let kind = FilterKind::by_name(match entry.filter.as_str() {
            "sobel" => "fp_sobel",
            other => other,
        })
        .unwrap_or_else(|| panic!("unknown filter {}", entry.filter));
        let frame = Frame::test_card(entry.width, entry.height);
        let kernel = match kind {
            FilterKind::Conv3x3 => Some(conv::gaussian3x3()),
            FilterKind::Conv5x5 => Some(conv::gaussian5x5()),
            _ => None,
        };
        let exe = rt.load(entry).expect("load");
        let got = exe.run(&frame, kernel.as_deref()).expect("run");
        let want = simulate(kind, fmt, &frame, kernel.as_deref());
        // bit-exact for correctly-rounded op filters; ulp-bounded for the
        // transcendental nlfilter and the clamp-only m>=52 format (see
        // runtime::golden_tolerance)
        let excess = fpspatial::runtime::golden_mismatch(&got, &want, &entry.filter, fmt.mantissa);
        assert_eq!(
            excess, 0.0,
            "{}: sim vs PJRT outside golden tolerance (excess = {excess:e}, raw max |d| = {:e})",
            entry.file,
            got.max_abs_diff(&want)
        );
        checked += 1;
    }
    println!("checked {checked} artifacts bit-exact");
}

/// The native-f64 software artifacts agree with the vectorized Rust
/// baselines (up to FMA reassociation in XLA).
#[test]
fn software_artifacts_match_rust_baselines() {
    let Some(rt) = runtime() else { return };
    // use the smallest software resolution for speed
    let (h, w) = (480, 640);
    let frame = Frame::test_card(w, h);

    // conv3x3
    let exe = rt.load_filter("conv3x3", None, h, w).expect("artifact");
    let k = conv::gaussian3x3();
    let got = exe.run(&frame, Some(&k)).expect("run");
    let want = fpspatial::filters::software::conv_sw(&frame, &k, 3);
    let rel = got
        .data
        .iter()
        .zip(&want.data)
        .map(|(a, b)| (a - b).abs() / b.abs().max(1.0))
        .fold(0.0f64, f64::max);
    assert!(rel < 1e-10, "conv3x3 rel diff {rel}");

    // median (pure selection — must be exactly equal to the two-footprint
    // algorithm; note the software row uses the same fig. 8 design)
    let exe = rt.load_filter("median", None, h, w).expect("artifact");
    let got = exe.run(&frame, None).expect("run");
    let want = fpspatial::video::map_windows(&frame, 3, |win| {
        let med5 = |idx: [usize; 5]| {
            let mut v = idx.map(|i| win[i]);
            v.sort_by(|a, b| a.partial_cmp(b).unwrap());
            v[2]
        };
        (med5(fpspatial::filters::median::FOOTPRINT_A)
            + med5(fpspatial::filters::median::FOOTPRINT_B))
            / 2.0
    });
    assert_eq!(got.max_abs_diff(&want), 0.0, "median exact mismatch");

    // nlfilter vs the native closure
    let exe = rt.load_filter("nlfilter", None, h, w).expect("artifact");
    let got = exe.run(&frame, None).expect("run");
    let want = fpspatial::filters::software::nlfilter_sw(
        &frame,
        3,
        &fpspatial::filters::software::eq2_native,
    );
    let rel = got
        .data
        .iter()
        .zip(&want.data)
        .map(|(a, b)| (a - b).abs() / b.abs().max(1e-12))
        .fold(0.0f64, f64::max);
    assert!(rel < 1e-9, "nlfilter rel diff {rel}");
}
