//! Session-reuse suite: one **long-lived** [`Session`] processing a
//! 16-frame synthetic video sequence must be bit-identical to 16 fresh
//! single-frame runs, for every [`ExecPlan`] variant in both numeric
//! modes — proving that the zero-steady-state-allocation streaming path
//! (warm engines, warm window generators, recycled scratch and frame
//! pools) never leaks state between frames.  Also pins the usable error
//! a reused session reports when the frame geometry changes mid-stream.

use fpspatial::coordinator::synth_sequence;
use fpspatial::filters::FilterKind;
use fpspatial::fpcore::{FloatFormat, OpMode};
use fpspatial::pipeline::{CompiledPipeline, ExecError, ExecPlan, Pipeline};
use fpspatial::video::Frame;

const F16: FloatFormat = FloatFormat::new(10, 5);
const F24: FloatFormat = FloatFormat::new(16, 7);

const EXECS: [ExecPlan; 4] = [
    ExecPlan::Scalar,
    ExecPlan::Batched,
    ExecPlan::Tiled { workers: 3 },
    ExecPlan::Streaming { workers: 2, reorder: 2 },
];

/// Bitwise frame comparison (catches even 0.0 vs -0.0 divergence).
fn assert_bit_identical(a: &Frame, b: &Frame, what: &str) {
    assert_eq!((a.width, a.height), (b.width, b.height), "{what}: shape");
    for (i, (x, y)) in a.data.iter().zip(&b.data).enumerate() {
        assert_eq!(
            x.to_bits(),
            y.to_bits(),
            "{what}: pixel {i} ({}, {}) differs: {x} vs {y}",
            i % a.width,
            i / a.width
        );
    }
}

/// The plans under test: a single filter (a chain of one), a uniform
/// two-stage chain, and a mixed-precision chain with an active
/// converter boundary.
fn plans(mode: OpMode) -> Vec<(&'static str, CompiledPipeline)> {
    vec![
        (
            "median",
            Pipeline::new().builtin(FilterKind::Median).format(F16).compile(mode).unwrap(),
        ),
        (
            "median->fp_sobel",
            Pipeline::new()
                .builtin(FilterKind::Median)
                .format(F16)
                .builtin(FilterKind::FpSobel)
                .format(F16)
                .compile(mode)
                .unwrap(),
        ),
        (
            "conv3x3@f24->median@f16 (mixed)",
            Pipeline::new()
                .builtin(FilterKind::Conv3x3)
                .format(F24)
                .builtin(FilterKind::Median)
                .format(F16)
                .compile(mode)
                .unwrap(),
        ),
    ]
}

/// A 16-frame synthetic sequence on a ragged width (37 = 2·LANES + 5) so
/// the lane-replication and border paths stay warm across frames.
fn sequence() -> Vec<Frame> {
    synth_sequence(37, 19, 16)
}

/// One long-lived session, 16 frames through `Session::process`, vs a
/// **fresh** plan + session per frame — bit-identical for every
/// `ExecPlan` × mode × plan shape.
#[test]
fn long_lived_session_matches_fresh_single_frame_runs() {
    let frames = sequence();
    for mode in [OpMode::Exact, OpMode::Poly] {
        for (label, plan) in plans(mode) {
            for exec in EXECS {
                let mut session = plan.session(exec).unwrap();
                for (i, f) in frames.iter().enumerate() {
                    let reused = session.process(f).unwrap();
                    // fresh everything: a cold session on a cold plan
                    let fresh_plans = plans(mode);
                    let fresh_plan =
                        &fresh_plans.iter().find(|(l, _)| *l == label).unwrap().1;
                    let fresh = fresh_plan.session(exec).unwrap().process(f).unwrap();
                    assert_bit_identical(
                        &reused,
                        &fresh,
                        &format!("{label} {mode:?} {exec} frame {i}"),
                    );
                }
            }
        }
    }
}

/// The long-lived session also matches the plan's sequential oracle on
/// every frame (transitively ties all plans to the reference semantics).
#[test]
fn long_lived_session_matches_the_oracle() {
    let frames = sequence();
    for mode in [OpMode::Exact, OpMode::Poly] {
        for (label, plan) in plans(mode) {
            for exec in EXECS {
                let mut session = plan.session(exec).unwrap();
                for (i, f) in frames.iter().enumerate() {
                    let got = session.process(f).unwrap();
                    let want = plan.run_frame_sequential(f);
                    assert_bit_identical(
                        &got,
                        &want,
                        &format!("{label} {mode:?} {exec} frame {i}"),
                    );
                }
            }
        }
    }
}

/// `process_sequence` (the pipelined bulk path, with in-flight frames and
/// the reorder window under `Streaming`) delivers the same bits in the
/// same order as frame-at-a-time `process` on a second session.
#[test]
fn process_sequence_matches_frame_at_a_time() {
    let frames = sequence();
    for (label, plan) in plans(OpMode::Exact) {
        for exec in EXECS {
            let mut bulk = plan.session(exec).unwrap();
            let mut outs: Vec<(u64, Frame)> = Vec::new();
            let m = bulk.process_sequence(frames.clone(), |seq, f| outs.push((seq, f))).unwrap();
            assert_eq!(m.frames, 16);
            assert!(outs.windows(2).all(|w| w[0].0 + 1 == w[1].0), "{label} {exec}: order");
            let mut single = plan.session(exec).unwrap();
            for ((seq, got), f) in outs.iter().zip(&frames) {
                let want = single.process(f).unwrap();
                assert_bit_identical(got, &want, &format!("{label} {exec} frame {seq}"));
            }
        }
    }
}

/// `process_into` with one reused output buffer is the zero-allocation
/// steady state; it must produce the same bits as `process`.
#[test]
fn process_into_reuses_buffers_bit_identically() {
    let frames = sequence();
    for (label, plan) in plans(OpMode::Exact) {
        for exec in EXECS {
            let mut session = plan.session(exec).unwrap();
            let mut out = Frame::new(0, 0);
            for (i, f) in frames.iter().enumerate() {
                session.process_into(f, &mut out).unwrap();
                let want = plan.run_frame_sequential(f);
                assert_bit_identical(&out, &want, &format!("{label} {exec} frame {i}"));
            }
        }
    }
}

/// A streaming `process_sequence` that errors mid-stream (size change
/// with frames still in flight) must not poison the session: the pool
/// discards its in-flight work, and after `reset()` the session
/// produces correct, current outputs again — not a stale completion
/// from the aborted sequence.
#[test]
fn streaming_error_mid_sequence_discards_in_flight_work() {
    let plan = Pipeline::new().builtin(FilterKind::Median).format(F16).compile(OpMode::Exact)
        .unwrap();
    let mut session = plan.session(ExecPlan::Streaming { workers: 2, reorder: 2 }).unwrap();
    // frames 0..5 are fine; frame 5 changes geometry while several
    // submissions are still outstanding (in-flight budget is 4)
    let mut frames = synth_sequence(37, 19, 5);
    frames.push(Frame::test_card(24, 16));
    let err = session.process_sequence(frames, |_, _| {}).unwrap_err();
    assert!(err.to_string().contains("24x16"), "{err}");
    // the pinned geometry still yields the *current* frame's output
    let probe = Frame::salt_pepper(37, 19, 0.2, 99);
    let got = session.process(&probe).unwrap();
    assert_bit_identical(&got, &plan.run_frame_sequential(&probe), "post-error process");
    // and reset + new geometry works too
    session.reset();
    let probe2 = Frame::test_card(24, 16);
    let got2 = session.process(&probe2).unwrap();
    assert_bit_identical(&got2, &plan.run_frame_sequential(&probe2), "post-reset process");
}

/// A non-finite pixel mid-sequence is rejected as a typed
/// [`ExecError::PoisonFrame`] naming the frame and the pixel, under
/// every `ExecPlan` — and the rejection does not poison the session:
/// the same session keeps producing oracle-identical output afterwards.
#[test]
fn poison_frame_mid_sequence_is_typed_and_recoverable() {
    let plan = Pipeline::new().builtin(FilterKind::Median).format(F16).compile(OpMode::Exact)
        .unwrap();
    for exec in EXECS {
        let mut frames = synth_sequence(37, 19, 5);
        frames[2].data[41] = f64::NAN;
        let mut session = plan.session(exec).unwrap();
        let err = session.process_sequence(frames, |_, _| {}).unwrap_err();
        match err.downcast_ref::<ExecError>() {
            Some(ExecError::PoisonFrame { frame_seq: 2, index: 41, value }) => {
                assert!(value.is_nan(), "{exec}");
            }
            other => panic!("{exec}: expected PoisonFrame at frame 2, got {other:?}"),
        }
        // the session keeps serving after the rejection
        let probe = Frame::salt_pepper(37, 19, 0.2, 7);
        let got = session.process(&probe).unwrap();
        assert_bit_identical(&got, &plan.run_frame_sequential(&probe), &format!("{exec} after"));
    }
}

/// Healthy runs report zero drops, zero deadline misses and zero worker
/// restarts — both in the per-run [`Metrics`] and in the session-lifetime
/// counters.
#[test]
fn fault_counters_stay_zero_on_healthy_runs() {
    for (label, plan) in plans(OpMode::Exact) {
        for exec in EXECS {
            let mut session = plan.session(exec).unwrap();
            let m = session.process_sequence(sequence(), |_, _| {}).unwrap();
            assert_eq!(m.frames, 16, "{label} {exec}");
            assert_eq!(
                (m.dropped, m.deadline_misses, m.worker_restarts),
                (0, 0, 0),
                "{label} {exec}"
            );
            assert_eq!(session.dropped(), 0, "{label} {exec}");
            assert_eq!(session.deadline_misses(), 0, "{label} {exec}");
            assert_eq!(session.worker_restarts(), 0, "{label} {exec}");
        }
    }
}

/// A reused session receiving a frame of a different size reports a
/// usable error naming both geometries (for every `ExecPlan` variant),
/// keeps working on the pinned size, and accepts the new size after
/// `reset()`.
#[test]
fn size_change_mid_stream_is_a_usable_error() {
    let plan = Pipeline::new().builtin(FilterKind::Median).format(F16).compile(OpMode::Exact)
        .unwrap();
    for exec in EXECS {
        let mut session = plan.session(exec).unwrap();
        let a = Frame::test_card(37, 19);
        let b = Frame::test_card(24, 16);
        session.process(&a).unwrap();
        let err = session.process(&b).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("37x19"), "{exec}: {msg}");
        assert!(msg.contains("24x16"), "{exec}: {msg}");
        assert!(msg.contains("reset"), "{exec}: {msg}");
        // the pinned geometry still works after the rejection
        let still = session.process(&a).unwrap();
        assert_bit_identical(&still, &plan.run_frame_sequential(&a), &format!("{exec} pinned"));
        // reset unpins; the new geometry is accepted and correct
        session.reset();
        let out = session.process(&b).unwrap();
        assert_bit_identical(&out, &plan.run_frame_sequential(&b), &format!("{exec} reset"));
    }
}
