//! Property-style randomized tests over the core invariants (the offline
//! crate set has no proptest; `util::rng::Rng` drives deterministic
//! randomized sweeps with explicit seeds — failures print the seed).

use fpspatial::fpcore::encode::{decode, encode};
use fpspatial::fpcore::format::FORMATS;
use fpspatial::fpcore::{quantize, FloatFormat, OpKind, OpMode};
use fpspatial::sim::netlist::Builder;
use fpspatial::sim::{Engine, RtlSim};
use fpspatial::util::rng::Rng;
use fpspatial::video::{map_windows, Frame};

/// quantize is idempotent, monotone, and within half-ulp of the input.
#[test]
fn quantize_properties() {
    for (key, fmt) in FORMATS {
        if fmt.mantissa > 50 {
            continue; // clamp-only regime
        }
        let mut rng = Rng::new(0xF00D + fmt.mantissa as u64);
        let mut prev_x = f64::NEG_INFINITY;
        let mut prev_q = f64::NEG_INFINITY;
        let mut xs: Vec<f64> = (0..4000)
            .map(|_| rng.wide_float(fmt.emin() - 2, fmt.emax() + 2))
            .collect();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for &x in &xs {
            let q = quantize(x, fmt);
            // idempotent
            assert_eq!(quantize(q, fmt), q, "{key} {x}");
            // monotone
            assert!(x >= prev_x);
            assert!(q >= prev_q, "{key}: quantize not monotone at {x}");
            prev_x = x;
            prev_q = q;
            // error bound for in-range normals
            let a = x.abs();
            if a >= fmt.min_normal() && a <= fmt.max_value() {
                let ulp_rel = 2.0_f64.powi(-(fmt.mantissa as i32 + 1));
                assert!(
                    (q - x).abs() <= a * ulp_rel * 1.0000001,
                    "{key}: rounding error too large at {x}: {q}"
                );
            }
        }
    }
}

/// encode/decode round-trips every quantized value.
#[test]
fn encode_decode_round_trip() {
    for (key, fmt) in FORMATS {
        if fmt.mantissa > 50 {
            continue;
        }
        let mut rng = Rng::new(0xBEEF + fmt.exponent as u64);
        for _ in 0..2000 {
            let x = rng.wide_float(fmt.emin(), fmt.emax());
            let q = quantize(x, fmt);
            let bits = encode(q, fmt);
            assert!(bits < (1u128 << fmt.width()) as u64 || fmt.width() == 64);
            assert_eq!(decode(bits, fmt), q, "{key}: {x} -> {q} -> {bits:#x}");
        }
    }
}

/// Random feed-forward netlists: the RTL simulator must align with the
/// functional engine at exactly `total_latency` — the scheduler's Δ
/// algebra holds for arbitrary DAGs, not just the paper's examples.
#[test]
fn random_netlists_rtl_alignment() {
    let fmt = FloatFormat::new(10, 5);
    for seed in 0..20u64 {
        let mut rng = Rng::new(1000 + seed);
        let mut b = Builder::new(fmt);
        let n_inputs = 2 + rng.below(4) as usize;
        let mut pool: Vec<_> = (0..n_inputs)
            .map(|i| b.input(&format!("x{i}")))
            .collect();
        let n_ops = 5 + rng.below(20) as usize;
        for _ in 0..n_ops {
            let a = pool[rng.below(pool.len() as u64) as usize];
            let c = pool[rng.below(pool.len() as u64) as usize];
            let out = match rng.below(8) {
                0 => b.add(a, c),
                1 => b.mul(a, c),
                2 => b.sqrt(a),
                3 => b.max_const(a, 1.0),
                4 => b.rsh(a, 1 + rng.below(3) as u32),
                5 => {
                    let (lo, hi) = b.cas(a, c);
                    pool.push(lo);
                    hi
                }
                6 => b.mul_const(a, 0.5 + rng.next_f64()),
                _ => b.op2(OpKind::Min, a, c),
            };
            pool.push(out);
        }
        let out_sig = *pool.last().unwrap();
        b.output("y", out_sig);
        let nl = b.build();
        let lat = nl.total_latency() as usize;

        let mut rtl = RtlSim::new(&nl, OpMode::Exact);
        let mut func = Engine::new(&nl, OpMode::Exact);
        let stream: Vec<Vec<f64>> = (0..lat + 30)
            .map(|_| (0..n_inputs).map(|_| rng.uniform(0.5, 200.0)).collect())
            .collect();
        let outs: Vec<f64> = stream.iter().map(|s| rtl.step(s)[0]).collect();
        for (t, s) in stream.iter().enumerate() {
            if t + lat < outs.len() {
                assert_eq!(
                    outs[t + lat],
                    func.eval(s)[0],
                    "seed {seed}: misalignment at pixel {t} (λ={lat})"
                );
            }
        }
    }
}

/// Filter outputs are always representable in their format (every op
/// rounds), for every filter and format.
#[test]
fn filter_outputs_are_format_values() {
    use fpspatial::filters::FilterKind;
    use fpspatial::pipeline::Pipeline;
    let frame = Frame::noise(24, 18, 99);
    for (_, fmt) in FORMATS {
        if fmt.mantissa > 50 {
            continue;
        }
        for kind in FilterKind::TABLE1 {
            let plan =
                Pipeline::new().builtin(kind).format(fmt).compile(OpMode::Exact).unwrap();
            let qframe = Frame {
                width: frame.width,
                height: frame.height,
                data: frame.data.iter().map(|&v| quantize(v, fmt)).collect(),
            };
            let out = plan.run_frame_sequential(&qframe);
            for (i, &v) in out.data.iter().enumerate() {
                assert_eq!(
                    quantize(v, fmt),
                    v,
                    "{} {}: output[{i}] = {v} not a format value",
                    kind.name(),
                    fmt
                );
            }
        }
    }
}

/// Median is idempotent-ish on impulse noise and bounded by window extremes.
#[test]
fn median_bounded_by_window() {
    use fpspatial::filters::FilterKind;
    use fpspatial::pipeline::{ExecPlan, Pipeline};
    let fmt = FloatFormat::new(23, 8);
    let plan =
        Pipeline::new().builtin(FilterKind::Median).format(fmt).compile(OpMode::Exact).unwrap();
    let frame = Frame::noise(32, 24, 5);
    let out = plan.session(ExecPlan::Batched).unwrap().process(&frame).unwrap();
    // output of the mean-of-two-medians is within [min, max] of the window
    let mins = map_windows(&frame, 3, |w| w.iter().copied().fold(f64::INFINITY, f64::min));
    let maxs = map_windows(&frame, 3, |w| w.iter().copied().fold(f64::NEG_INFINITY, f64::max));
    for i in 0..out.data.len() {
        assert!(out.data[i] >= mins.data[i] - 1e-9 && out.data[i] <= maxs.data[i] + 1e-9);
    }
}

/// Linearity: conv(a·x + b·y) == a·conv(x) + b·conv(y) in wide format
/// (up to per-op rounding, checked with tight tolerance at m=39).
#[test]
fn convolution_linearity() {
    use fpspatial::filters::conv::conv_netlist;
    let fmt = FloatFormat::new(39, 8);
    let mut rng = Rng::new(2024);
    let k: Vec<f64> = (0..9).map(|_| rng.uniform(-1.0, 1.0)).collect();
    let nl = conv_netlist(fmt, 3, &k);
    let mut eng = Engine::new(&nl, OpMode::Exact);
    for _ in 0..200 {
        let x: Vec<f64> = (0..9).map(|_| rng.uniform(0.0, 100.0)).collect();
        let y: Vec<f64> = (0..9).map(|_| rng.uniform(0.0, 100.0)).collect();
        let sum: Vec<f64> = x.iter().zip(&y).map(|(a, b)| a + b).collect();
        let fx = eng.eval(&x)[0];
        let fy = eng.eval(&y)[0];
        let fs = eng.eval(&sum)[0];
        assert!(
            (fs - (fx + fy)).abs() <= (fx + fy).abs().max(1.0) * 1e-9,
            "{fs} vs {}",
            fx + fy
        );
    }
}

/// DSL error paths: malformed programs fail with diagnostics, never panic.
#[test]
fn dsl_failure_injection() {
    let cases = [
        ("", "missing"),                                     // no use float
        ("use float(10,5);\nz = sqrt(", "unexpected"),      // truncated
        ("use float(10,5);\nvar float w[4][4];\nw = sliding_window(pix_i, 4, 4);", "odd"),
        ("use float(0, 5);\nvar float x;", "unsupported"),
        ("use float(10,5);\nvar float x;\noutput x;\nx = nosuch(x);", ""),
        ("use float(10,5);\nvar float K[2][2];\nK = [[1.0],[2.0, 3.0]];", "ragged"),
    ];
    for (src, needle) in cases {
        let res = fpspatial::dsl::compile(src, "bad");
        let err = format!("{:#}", res.expect_err(src));
        assert!(
            needle.is_empty() || err.to_lowercase().contains(needle),
            "{src:?}: {err}"
        );
    }
}

/// quantize is idempotent at the e/m boundary cases of every format:
/// saturation, subnormal flush, signed zeros, infinities, and values a
/// fraction of an ulp around the rounding thresholds.
#[test]
fn quantize_idempotent_at_boundaries() {
    for (key, fmt) in FORMATS {
        if fmt.mantissa > 50 {
            continue; // clamp-only regime
        }
        let ulp = 2.0_f64.powi(-(fmt.mantissa as i32));
        let mx = fmt.max_value();
        let mn = fmt.min_normal();
        let cases = [
            0.0,
            -0.0,
            mn,
            -mn,
            mn * (1.0 - 1e-12), // just below the normal range: flushes
            mn / 2.0,           // subnormal: flushes
            mn * (1.0 + ulp),   // smallest normal + 1 ulp
            mx,
            -mx,
            mx * (1.0 + 1e-12), // just above: saturates
            mx * 2.0,
            f64::INFINITY,
            f64::NEG_INFINITY,
            1.0,
            1.0 + ulp,
            1.0 + ulp / 3.0, // rounds down
            1.0 + 2.0 * ulp / 3.0, // rounds up
            2.0 - ulp,       // mantissa all-ones
            255.0,
        ];
        for x in cases {
            let q = quantize(x, fmt);
            let qq = quantize(q, fmt);
            assert_eq!(
                qq.to_bits(),
                q.to_bits(),
                "{key}: quantize not idempotent at {x} ({q} -> {qq})"
            );
        }
    }
}

/// encode/decode round-trips the e/m boundary values exactly (the hex
/// constants the SystemVerilog generator emits must decode back to the
/// value the simulator computes with).
#[test]
fn format_round_trip_at_boundaries() {
    for (key, fmt) in FORMATS {
        if fmt.mantissa > 50 {
            continue;
        }
        let ulp = 2.0_f64.powi(-(fmt.mantissa as i32));
        let mx = fmt.max_value();
        let mn = fmt.min_normal();
        let boundary = [
            0.0,
            mn,
            -mn,
            mn * (1.0 + ulp),
            mx,
            -mx,
            mx / 2.0,
            1.0,
            1.0 + ulp,
            2.0 - ulp,
            -(2.0 - ulp),
        ];
        for v in boundary {
            let q = quantize(v, fmt); // all values above are representable
            assert_eq!(q.to_bits(), v.to_bits(), "{key}: {v} should be representable");
            let bits = encode(q, fmt);
            assert!(
                bits < (1u128 << fmt.width()) as u64 || fmt.width() == 64,
                "{key}: encode({q}) = {bits:#x} overflows {} bits",
                fmt.width()
            );
            assert_eq!(decode(bits, fmt), q, "{key}: {v} -> {bits:#x}");
        }
        // saturated / flushed values round-trip to their quantized form
        for v in [mx * 4.0, mn / 4.0, -mx * 4.0] {
            let q = quantize(v, fmt);
            assert_eq!(decode(encode(v, fmt), fmt), q, "{key}: {v}");
        }
    }
}

/// Scalar [`Engine`] vs lane-batched `BatchEngine` consistency per
/// operator: single-op netlists, every lane bit-identical to a scalar
/// evaluation of the same window, in both numeric modes.
#[test]
fn scalar_vs_batched_op_consistency() {
    use fpspatial::sim::{BatchEngine, SignalId, LANES};

    let fmt = FloatFormat::new(10, 5);
    type BuildFn = fn(&mut Builder, SignalId, SignalId) -> Vec<SignalId>;
    let ops: [(&str, BuildFn); 16] = [
        ("add", |b, x, y| vec![b.add(x, y)]),
        ("sub", |b, x, y| vec![b.op2(OpKind::Sub, x, y)]),
        ("mul", |b, x, y| vec![b.mul(x, y)]),
        ("mul_const", |b, x, _| vec![b.mul_const(x, 0.8125)]),
        ("div", |b, x, y| vec![b.div(x, y)]),
        ("sqrt", |b, x, _| vec![b.sqrt(x)]),
        ("log2", |b, x, _| vec![b.log2(x)]),
        ("exp2", |b, x, _| {
            // keep exp2 in range: exp2(log2(x) / 8)
            let l = b.log2(x);
            let s = b.rsh(l, 3);
            vec![b.exp2(s)]
        }),
        ("max", |b, x, y| vec![b.op2(OpKind::Max, x, y)]),
        ("min", |b, x, y| vec![b.op2(OpKind::Min, x, y)]),
        ("max_const", |b, x, _| vec![b.max_const(x, 1.0)]),
        ("rsh", |b, x, _| vec![b.rsh(x, 2)]),
        ("lsh", |b, x, _| vec![b.lsh(x, 1)]),
        ("cas", |b, x, y| {
            let (lo, hi) = b.cas(x, y);
            vec![lo, hi]
        }),
        ("convert_widen", |b, x, _| {
            vec![b.op1(OpKind::Convert(FloatFormat::new(16, 7)), x)]
        }),
        ("convert_narrow", |b, x, _| {
            vec![b.op1(OpKind::Convert(FloatFormat::new(7, 6)), x)]
        }),
    ];
    for (name, build) in ops {
        let mut b = Builder::new(fmt);
        let x = b.input("x");
        let y = b.input("y");
        let outs = build(&mut b, x, y);
        let n_out = outs.len();
        for (i, sig) in outs.into_iter().enumerate() {
            b.output(&format!("o{i}"), sig);
        }
        let nl = b.build();
        for mode in [OpMode::Exact, OpMode::Poly] {
            let mut scalar = Engine::new(&nl, mode);
            let mut batch = BatchEngine::new(&nl, mode);
            let mut rng = Rng::new(0xC0FFEE ^ name.len() as u64);
            for round in 0..8 {
                let mut xs = [0.0; LANES];
                let mut ys = [0.0; LANES];
                for j in 0..LANES {
                    xs[j] = rng.uniform(0.5, 255.0);
                    ys[j] = rng.uniform(0.5, 255.0);
                }
                let mut out = vec![[0.0; LANES]; n_out];
                batch.eval_lanes(&[xs, ys], &mut out);
                for j in 0..LANES {
                    let want = scalar.eval(&[xs[j], ys[j]]);
                    for (port, w) in want.iter().enumerate() {
                        assert_eq!(
                            out[port][j].to_bits(),
                            w.to_bits(),
                            "{name} {mode:?} round {round} lane {j} port {port}: {} vs {w}",
                            out[port][j]
                        );
                    }
                }
            }
        }
    }
}

/// Inter-format conversion properties over every ordered pair of the
/// paper's five formats: converted values live on the destination grid,
/// conversion is idempotent, narrowing equals a direct quantize, and a
/// lossless widening round-trips bit-exactly.
#[test]
fn converter_round_trip_properties() {
    use fpspatial::fpcore::{convert, FmtConvert};
    for (sk, src) in FORMATS {
        if src.mantissa > 50 {
            continue; // clamp-only regime has no distinct grid to assert
        }
        for (dk, dst) in FORMATS {
            let c = FmtConvert::new(src, dst);
            let mut rng = Rng::new(0xCAFE ^ ((src.mantissa as u64) << 8) ^ dst.mantissa as u64);
            for _ in 0..1500 {
                // start from a genuine src-format value
                let x = quantize(rng.wide_float(src.emin() - 2, src.emax() + 2), src);
                let y = c.apply(x);
                // the free function and the struct agree
                assert_eq!(y.to_bits(), convert(x, src, dst).to_bits(), "{sk}->{dk} {x}");
                // result is on the dst grid, and conversion is idempotent
                assert_eq!(quantize(y, dst).to_bits(), y.to_bits(), "{sk}->{dk} {x}");
                assert_eq!(c.apply(y).to_bits(), y.to_bits(), "{sk}->{dk} {x}");
                // narrowing is exactly quantize-into-dst
                assert_eq!(y.to_bits(), quantize(x, dst).to_bits(), "{sk}->{dk} {x}");
                // lossless widening round-trips bit-exactly
                if c.is_lossless() {
                    assert_eq!(y.to_bits(), x.to_bits(), "{sk}->{dk}: widening must be exact");
                    let back = FmtConvert::new(dst, src);
                    assert_eq!(back.apply(y).to_bits(), x.to_bits(), "{sk}->{dk} round trip");
                }
            }
            // boundary values saturate/flush exactly like quantize
            for x in [src.max_value(), -src.max_value(), src.min_normal(), 0.0, -0.0] {
                assert_eq!(c.apply(x).to_bits(), quantize(x, dst).to_bits(), "{sk}->{dk} {x}");
            }
        }
    }
}

/// A netlist-embedded Convert node behaves exactly like quantize into
/// the destination — through the scalar engine, in both modes, and the
/// RTL simulator honours its 2-cycle latency.
#[test]
fn convert_node_in_a_netlist() {
    use fpspatial::sim::RtlSim;
    let src = FloatFormat::new(16, 7);
    let dst = FloatFormat::new(10, 5);
    let mut b = Builder::new(src);
    let x = b.input("x");
    let y = b.op1(OpKind::Convert(dst), x);
    b.output("y", y);
    let nl = b.build();
    assert_eq!(nl.total_latency(), 2);
    for mode in [OpMode::Exact, OpMode::Poly] {
        let mut eng = Engine::new(&nl, mode);
        let mut rng = Rng::new(0xD057 + mode as u64);
        for _ in 0..500 {
            let v = quantize(rng.uniform(-300.0, 300.0), src);
            assert_eq!(eng.eval(&[v])[0].to_bits(), quantize(v, dst).to_bits());
        }
    }
    let mut rtl = RtlSim::new(&nl, OpMode::Exact);
    let stream: Vec<f64> = (0..20).map(|i| i as f64 * 1.625).collect();
    let outs: Vec<f64> = stream.iter().map(|&v| rtl.step(&[v])[0]).collect();
    for (t, &v) in stream.iter().enumerate() {
        if t + 2 < outs.len() {
            assert_eq!(outs[t + 2].to_bits(), quantize(v, dst).to_bits(), "pixel {t}");
        }
    }
}

/// Window generator == jnp pad(edge) semantics on random frames/sizes.
#[test]
fn window_generator_random_sizes() {
    let mut rng = Rng::new(31337);
    for _ in 0..15 {
        let w = 6 + rng.below(40) as usize;
        let h = 5 + rng.below(30) as usize;
        let f = Frame::noise(w, h, rng.next_u64());
        for k in [3usize, 5] {
            if w < k || h < k {
                continue;
            }
            let got = map_windows(&f, k, |win| win.iter().sum::<f64>());
            // reference via clamped indexing
            let p = (k / 2) as isize;
            for y in 0..h {
                for x in 0..w {
                    let mut want = 0.0;
                    for dy in -p..=p {
                        for dx in -p..=p {
                            want += f.get_clamped(x as isize + dx, y as isize + dy);
                        }
                    }
                    assert_eq!(got.get(x, y), want, "{w}x{h} k={k} at ({x},{y})");
                }
            }
        }
    }
}

/// Every fused superinstruction of the tape compiler — MAC (both
/// operand orders), coefficient MAC, TreeReduce, FoldMax, Relu, and
/// compile-time-folded constants — is bit-identical to its unfused step
/// sequence (the scalar [`Engine`] oracle) across a 5×5 grid of
/// `(mantissa, exponent)` formats × Exact/Poly.  Each case also pins
/// its pass-stats so the kernel provably *runs* the fused path instead
/// of silently falling back to plain ops.
#[test]
fn fused_superinstructions_bit_identical_to_unfused() {
    use std::sync::Arc;

    use fpspatial::sim::{compile, KernelExec, Netlist, PassStats, SignalId, LANES};

    type CheckFn = fn(&PassStats) -> bool;
    type BuildFn = fn(&mut Builder) -> Vec<SignalId>;
    let cases: [(&str, usize, BuildFn, CheckFn); 8] = [
        ("mac", 3, |b| {
            let x = b.input("x");
            let w = b.input("w");
            let acc = b.input("acc");
            let p = b.mul(x, w);
            vec![b.add(p, acc)]
        }, |s| s.macs == 1),
        ("mac_acc_first", 3, |b| {
            let x = b.input("x");
            let w = b.input("w");
            let acc = b.input("acc");
            let p = b.mul(x, w);
            vec![b.add(acc, p)]
        }, |s| s.macs == 1),
        ("mac_const", 2, |b| {
            let x = b.input("x");
            let acc = b.input("acc");
            let p = b.mul_const(x, 0.3125);
            vec![b.add(p, acc)]
        }, |s| s.macs == 1),
        ("mac_const_acc_first", 2, |b| {
            let x = b.input("x");
            let acc = b.input("acc");
            let p = b.mul_const(x, 0.3125);
            vec![b.add(acc, p)]
        }, |s| s.macs == 1),
        ("tree_reduce", 5, |b| {
            let terms: Vec<SignalId> = (0..5).map(|i| b.input(&format!("t{i}"))).collect();
            vec![b.adder_tree(&terms)]
        }, |s| s.tree_groups >= 1 || s.macs >= 1),
        ("fold_max", 4, |b| {
            let t: Vec<SignalId> = (0..4).map(|i| b.input(&format!("t{i}"))).collect();
            let m0 = b.op2(OpKind::Max, t[0], t[1]);
            let m1 = b.op2(OpKind::Max, m0, t[2]);
            vec![b.op2(OpKind::Max, m1, t[3])]
        }, |s| s.fold_maxes == 1 && s.fold_max_terms == 3),
        ("relu", 1, |b| {
            let x = b.input("x");
            vec![b.max_const(x, 0.0)]
        }, |s| s.relus == 1),
        ("folded_const", 1, |b| {
            // x · (2 + 3): the add folds at compile time, the multiply
            // becomes a mul-by-immediate
            let x = b.input("x");
            let c2 = b.constant(2.0);
            let c3 = b.constant(3.0);
            let s = b.add(c2, c3);
            vec![b.mul(x, s)]
        }, |s| s.folded >= 1),
    ];

    // the 5×5 (m, e) grid of the sweep
    let mantissas = [4u32, 7, 10, 16, 23];
    let exponents = [4u32, 5, 6, 7, 8];
    for (name, n_in, build, check) in cases {
        for m in mantissas {
            for e in exponents {
                let fmt = FloatFormat::new(m, e);
                // constants quantize at build time, so rebuild per format
                let nl: Netlist = {
                    let mut b = Builder::new(fmt);
                    let outs = build(&mut b);
                    for (i, sig) in outs.into_iter().enumerate() {
                        b.output(&format!("o{i}"), sig);
                    }
                    b.build()
                };
                for mode in [OpMode::Exact, OpMode::Poly] {
                    let kernel = Arc::new(compile(&nl, mode));
                    assert!(
                        check(&kernel.stats()),
                        "{name} m{m}e{e} {mode:?}: fusion missing: {:?}",
                        kernel.stats()
                    );
                    let mut fused = KernelExec::new(kernel);
                    let mut oracle = Engine::new(&nl, mode);
                    let mut rng =
                        Rng::new(0xF05E ^ ((m as u64) << 16) ^ ((e as u64) << 8) ^ name.len() as u64);
                    for round in 0..4 {
                        let mut in_lanes = vec![[0.0; LANES]; n_in];
                        for lane in in_lanes.iter_mut() {
                            for v in lane.iter_mut() {
                                // signed range so Max/Relu paths see both signs
                                *v = quantize(rng.uniform(-255.0, 255.0), fmt);
                            }
                        }
                        let mut out = vec![[0.0; LANES]; 1];
                        fused.eval_lanes(&in_lanes, &mut out);
                        for j in 0..LANES {
                            let ins: Vec<f64> = in_lanes.iter().map(|l| l[j]).collect();
                            let want = oracle.eval(&ins);
                            assert_eq!(
                                out[0][j].to_bits(),
                                want[0].to_bits(),
                                "{name} m{m}e{e} {mode:?} round {round} lane {j}: {} vs {}",
                                out[0][j],
                                want[0]
                            );
                        }
                    }
                }
            }
        }
    }
}
