//! Plan-optimizer tests: conv fusion correctness (structural equality
//! against a hand-composed kernel, pinned accuracy bounds, honest
//! resource movement) and format-search invariants (determinism, Pareto
//! non-domination, refusal diagnostics).

use fpspatial::filters::conv::{gaussian3x3, gaussian5x5};
use fpspatial::filters::{FilterKind, FilterSpec, HwFilter};
use fpspatial::fpcore::{FloatFormat, OpMode};
use fpspatial::opt::{self, compose_kernels, SearchConfig};
use fpspatial::pipeline::{CompiledPipeline, Pipeline};
use fpspatial::sim::Builder;
use fpspatial::video::StageGeometry;

const F16: FloatFormat = FloatFormat::new(10, 5);
const F24: FloatFormat = FloatFormat::new(16, 7);

fn plan_of(stages: Vec<HwFilter>, mode: OpMode) -> CompiledPipeline {
    Pipeline::from_stages(stages).compile(mode).expect("test plan compiles")
}

fn conv3(fmt: FloatFormat) -> HwFilter {
    HwFilter::new(FilterKind::Conv3x3, fmt).unwrap()
}

/// A 1×1 pointwise linear scale stage (`out = c·px`), built straight
/// from the public `HwFilter` fields — `conv_rect` refuses 1×1 windows,
/// but the streaming runtime and the fusion tap-extractor both handle
/// them (ReLU is the precedent).
fn scale1x1(fmt: FloatFormat, c: f64) -> HwFilter {
    let mut b = Builder::new(fmt);
    let x = b.input("px");
    let y = b.mul_const(x, c);
    b.output("out", y);
    HwFilter {
        spec: FilterSpec::Dsl { name: "scale1x1".into() },
        fmt,
        geom: StageGeometry::square(1),
        netlist: b.build(),
    }
}

// ---------------------------------------------------------------------------
// Fusion: structural correctness
// ---------------------------------------------------------------------------

/// The default 3×3 Gaussian composed with itself IS the built-in 5×5
/// Gaussian — both are dyadic-rational binomial kernels, so the
/// composition is exact in f64, not merely close.
#[test]
fn composed_gaussian3x3_is_exactly_gaussian5x5() {
    let c = compose_kernels(&gaussian3x3(), (3, 3), &gaussian3x3(), (3, 3));
    assert_eq!(c, gaussian5x5());
}

/// Fusing two default conv3x3 stages yields a stage whose netlist is
/// *bit-identical* (fingerprint equality) to a hand-composed 5×5
/// convolution built from `compose_kernels`.
#[test]
fn fused_conv3x3_pair_matches_hand_composed_conv5x5() {
    for mode in [OpMode::Exact, OpMode::Poly] {
        let plan = plan_of(vec![conv3(F16), conv3(F16)], mode);
        let (fused, report) = plan.fused().expect("3x3∘3x3 fuses");
        assert_eq!(fused.len(), 1, "two convs collapse into one stage");
        assert_eq!(report.stages_before, 2);
        assert_eq!(report.stages_after, 1);
        assert_eq!(report.pairs.len(), 1);

        let k = compose_kernels(&gaussian3x3(), (3, 3), &gaussian3x3(), (3, 3));
        let hand = HwFilter::conv_rect(F16, 5, 5, &k).unwrap();
        let got = &fused.stages()[0];
        assert_eq!(got.geom, hand.geom);
        assert_eq!(
            got.netlist.fingerprint(),
            hand.netlist.fingerprint(),
            "fused netlist must be structurally identical to the hand-composed 5x5"
        );
    }
}

/// Pinned accuracy bounds for the 3×3∘3×3 fusion, in both numeric
/// modes: the drift vs the unfused sequential oracle stays within a few
/// thousand output-format ulps and the frames stay visually identical.
#[test]
fn fusion_drift_stays_within_pinned_bounds() {
    let frames = opt::reference_frames(96, 64);
    for mode in [OpMode::Exact, OpMode::Poly] {
        let plan = plan_of(vec![conv3(F16), conv3(F16)], mode);
        let (_, report) = plan.fused_with(&frames, 1920).unwrap();
        assert!(
            report.accuracy.max_ulp <= 4096.0,
            "{mode:?}: fusion drift {} ulp exceeds the pinned bound",
            report.accuracy.max_ulp
        );
        assert!(
            report.accuracy.psnr >= 30.0,
            "{mode:?}: fusion PSNR {:.1} dB below the pinned bound",
            report.accuracy.psnr
        );
    }
}

/// The report is honest about where a 3×3∘3×3 fusion wins: latency and
/// a whole per-row pass go down, line-buffer storage ties (2+2 lines vs
/// 4), while the composed datapath itself *grows* (signed deltas).
#[test]
fn fusion_report_carries_signed_deltas() {
    let plan = plan_of(vec![conv3(F16), conv3(F16)], OpMode::Exact);
    let (_, report) = plan.fused().unwrap();
    assert!(
        report.latency_after < report.latency_before,
        "one composed adder tree must be shallower than two chained ones"
    );
    assert!(report.line_buffer_bits_after <= report.line_buffer_bits_before);
    let p = &report.pairs[0];
    assert!(p.latency_delta < 0);
    assert!(
        p.lut_delta > 0 && p.dsp_delta > 0,
        "a 5x5 datapath is bigger than two 3x3s — the report must not hide it"
    );
}

/// Fusing a pointwise 1×1 scale into its upstream conv is the
/// all-axes-win case: the scale's window generator and datapath vanish
/// entirely.
#[test]
fn fusing_a_pointwise_scale_shrinks_every_axis() {
    let plan = plan_of(vec![conv3(F16), scale1x1(F16, 0.5)], OpMode::Exact);
    let (fused, report) = plan.fused().expect("conv3x3∘scale fuses");
    assert_eq!(fused.len(), 1);
    let g = fused.stages()[0].geom;
    assert_eq!((g.win_h, g.win_w), (3, 3), "1x1 composition keeps the 3x3 window");
    assert!(report.usage_after.luts < report.usage_before.luts);
    assert!(report.usage_after.ffs < report.usage_before.ffs);
    assert!(report.usage_after.dsps <= report.usage_before.dsps);
    assert!(report.latency_after < report.latency_before);
    assert!(report.line_buffer_bits_after <= report.line_buffer_bits_before);
}

// ---------------------------------------------------------------------------
// Fusion: refusal diagnostics
// ---------------------------------------------------------------------------

#[test]
fn fuse_refuses_strided_boundary_with_reason() {
    let plan = plan_of(vec![conv3(F16).with_stride(2), conv3(F16)], OpMode::Exact);
    let err = plan.fused().unwrap_err().to_string();
    assert!(err.contains("no fusible stage boundary"), "got: {err}");
    assert!(err.contains("strided stage"), "got: {err}");
}

#[test]
fn fuse_refuses_non_linear_boundary_with_reason() {
    let median = HwFilter::new(FilterKind::Median, F16).unwrap();
    let plan = plan_of(vec![median, conv3(F16)], OpMode::Exact);
    let err = plan.fused().unwrap_err().to_string();
    assert!(err.contains("no fusible stage boundary"), "got: {err}");
    assert!(err.contains("not a linear convolution"), "got: {err}");
}

#[test]
fn fuse_refuses_mixed_format_boundary_with_reason() {
    let plan = plan_of(vec![conv3(F16), conv3(F24)], OpMode::Exact);
    let err = plan.fused().unwrap_err().to_string();
    assert!(err.contains("no fusible stage boundary"), "got: {err}");
    assert!(err.contains("mixed-format boundary"), "got: {err}");
}

// ---------------------------------------------------------------------------
// Format search
// ---------------------------------------------------------------------------

fn search_cfg() -> SearchConfig {
    SearchConfig {
        psnr_target: Some(40.0),
        line_width: 256,
        beam: 2,
        ..SearchConfig::default()
    }
}

/// Same plan, same frames, same config → bit-identical search results.
/// The memoized walk has no hidden iteration-order dependence.
#[test]
fn search_is_deterministic() {
    let frames = opt::reference_frames(48, 32);
    let plan = plan_of(vec![conv3(F24), HwFilter::relu(F24)], OpMode::Exact);
    let cfg = search_cfg();
    let a = opt::search_formats(&plan, &frames, &cfg).unwrap();
    let b = opt::search_formats(&plan, &frames, &cfg).unwrap();
    assert_eq!(a.evaluated, b.evaluated);
    assert_eq!(a.front.len(), b.front.len());
    for (x, y) in a.front.iter().zip(&b.front) {
        assert_eq!(x.format_names(), y.format_names());
        assert_eq!(x.psnr.to_bits(), y.psnr.to_bits());
        assert_eq!(x.max_ulp.to_bits(), y.max_ulp.to_bits());
        assert_eq!((x.luts, x.dsps, x.bram_bits), (y.luts, y.dsps, y.bram_bits));
    }
    assert_eq!(
        a.chosen.as_ref().map(|p| p.format_names()),
        b.chosen.as_ref().map(|p| p.format_names())
    );
}

/// Every pair of front points is mutually non-dominated, the front is
/// non-empty, and the chosen point (the search had a reachable PSNR
/// target) meets that target at no more area than the widest uniform.
#[test]
fn front_is_non_dominated_and_chosen_is_feasible() {
    let frames = opt::reference_frames(48, 32);
    let plan = plan_of(vec![conv3(F24), HwFilter::relu(F24)], OpMode::Exact);
    let cfg = search_cfg();
    let res = opt::search_formats(&plan, &frames, &cfg).unwrap();

    assert!(!res.front.is_empty());
    assert!(res.evaluated >= 25, "at minimum the 25 uniform lattice points are scored");
    for (i, p) in res.front.iter().enumerate() {
        for (j, q) in res.front.iter().enumerate() {
            if i != j {
                assert!(
                    !p.dominates(q),
                    "front point {} dominates {} — front is not a Pareto front",
                    p.format_names(),
                    q.format_names()
                );
            }
        }
    }

    let chosen = res.chosen.expect("psnr=40 is reachable on the lattice");
    assert!(cfg.feasible(&chosen));
    let widest = vec![FloatFormat::new(23, 10); plan.len()];
    let widest_pt = opt::evaluate_point(&plan, &frames, &widest, cfg.line_width).unwrap();
    assert!(
        chosen.luts <= widest_pt.luts,
        "the cheapest feasible point can never cost more than uniform m23e10"
    );
}
