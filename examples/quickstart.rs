//! Quickstart: the full DSL → hardware flow in one file.
//!
//! 1. compile the paper's fig. 12 program (z = sqrt(xy/(x+y))) to
//!    SystemVerilog and inspect the schedule;
//! 2. promote the fig. 14 conv3x3 program to a first-class runtime filter
//!    (`HwFilter::from_dsl`) and stream a frame through the lane-batched
//!    hot path;
//! 3. estimate its Zybo Z7-20 resource usage.
//!
//! Run: `cargo run --release --example quickstart`

use anyhow::Result;
use fpspatial::dsl;
use fpspatial::fpcore::OpMode;
use fpspatial::pipeline::{ExecPlan, Pipeline};
use fpspatial::resources::{estimate, ZYBO_Z7_20};
use fpspatial::sim::Engine;
use fpspatial::video::Frame;

const FIG12: &str = include_str!("dsl/fig12.dsl");
const CONV: &str = include_str!("dsl/conv3x3.dsl");

fn main() -> Result<()> {
    // --- 1. scalar program → SystemVerilog --------------------------------
    let compiled = dsl::compile(FIG12, "fp_func")?;
    println!("fig. 12 program  : z = sqrt((x*y)/(x+y)) in {}", compiled.fmt);
    println!("  total latency  : {} cycles", compiled.netlist.total_latency());
    println!("  delay registers: {}", compiled.netlist.delay_registers());

    let sv = dsl::sverilog::generate(&compiled);
    println!(
        "  generated SV   : {} lines (DSL was {} lines)",
        sv.lines().count(),
        FIG12.lines().count()
    );

    // evaluate the datapath numerically
    let mut eng = Engine::new(&compiled.netlist, OpMode::Exact);
    let z = eng.eval(&[3.0, 6.0])[0];
    println!("  f(3, 6)        = {z}  (= sqrt(2) rounded into float16(10,5))");

    // --- 2. window program → first-class runtime filter -------------------
    // The same source that generates SystemVerilog also runs as a filter:
    // Pipeline::dsl compiles it into an execution plan on the
    // lane-batched/tiled hot path (a single filter is a chain of one).
    let plan = Pipeline::new().dsl_named(CONV, "conv3x3_top").compile(OpMode::Exact)?;
    let frame = Frame::test_card(128, 96);
    let out = plan.session(ExecPlan::Batched)?.process(&frame)?;
    println!(
        "\nfig. 14 conv3x3  : filtered a {}x{} test card ({} via Pipeline::dsl, λ = {} cycles)",
        frame.width,
        frame.height,
        plan.name(),
        plan.datapath_latency()
    );
    println!("  in[64,48]={:.1}  out[64,48]={:.1}", frame.get(64, 48), out.get(64, 48));
    out.save_pgm(std::env::temp_dir().join("quickstart_conv.pgm"))?;

    // --- 3. FPGA resource estimate ----------------------------------------
    let hw = &plan.stages()[0];
    let usage = estimate(&hw.netlist, Some((hw.geom, 1920)));
    let u = usage.utilization(ZYBO_Z7_20);
    println!("\nZybo Z7-20 estimate for conv3x3 @ 1080p:");
    println!("  {} LUT ({:.1}%), {} FF ({:.1}%), {:.1} BRAM36, {} DSP",
        usage.luts, u[0], usage.ffs, u[1], usage.bram36, usage.dsps);
    println!("  fits: {}", usage.fits(ZYBO_Z7_20));
    Ok(())
}
