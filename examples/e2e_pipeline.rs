//! End-to-end driver — proves all three layers compose on a real workload.
//!
//! Streams a 64-frame synthetic video sequence through the four Table-I
//! filters three ways:
//!
//!   1. **hardware model** — the cycle-simulated custom-float datapaths
//!      behind the line-buffer window generator (Layer 3 coordinator with
//!      a multi-worker pipeline);
//!   2. **software baselines** — vectorized compiled loops for the linear
//!      and median filters, the interpreted MATLAB-`nlfilter`-style path
//!      for the generic filter;
//!   3. **PJRT golden** — the AOT-lowered JAX/Pallas artifact for each
//!      filter at the golden resolution, checked *bit-exact* against the
//!      simulator.
//!
//! Reports the Table-I-shaped FPS table, the ~810× nlfilter headline, and
//! the pixel-clock hardware rates.  Results are recorded in EXPERIMENTS.md.
//!
//! Run: `cargo run --release --example e2e_pipeline`  (after `make artifacts`)

use std::time::Instant;

use anyhow::Result;
use fpspatial::coordinator::synth_sequence;
use fpspatial::dsl;
use fpspatial::filters::{conv, software, FilterKind};
use fpspatial::fpcore::{FloatFormat, OpMode};
use fpspatial::pipeline::{ExecPlan, Pipeline};
use fpspatial::video::T1080P;

const FMT: FloatFormat = FloatFormat::new(10, 5);
const W: usize = 320;
const H: usize = 240;
const FRAMES: usize = 64;

fn main() -> Result<()> {
    println!("=== fpspatial end-to-end driver ===\n");
    let seq = synth_sequence(W, H, FRAMES);
    println!("workload: {FRAMES} frames @ {W}x{H} (moving test card + noise bursts)\n");

    // --- 1. hardware model through streaming sessions ---------------------
    println!("[1] hardware-model pipeline (cycle-simulated custom float16(10,5))");
    let mut hw_rates = Vec::new();
    for kind in FilterKind::TABLE1 {
        let plan = Pipeline::new().builtin(kind).format(FMT).compile(OpMode::Exact)?;
        let mut session = plan.session(ExecPlan::streaming(4))?;
        let mut n_out = 0usize;
        let m = session.process_sequence(seq.clone(), |_, _| n_out += 1)?;
        assert_eq!(n_out, FRAMES);
        println!(
            "    {:<9} {:>7.2} sim-FPS ({:>6.1} Mpx/s wall-clock), datapath λ = {} cycles",
            kind.name(),
            m.fps(),
            m.pixel_rate(W, H) / 1e6,
            plan.datapath_latency()
        );
        hw_rates.push((kind, m));
    }
    println!(
        "    on the FPGA pixel clock every filter streams II=1: {:.0} FPS @1080p\n",
        T1080P.fpga_fps()
    );

    // --- 2. software baselines --------------------------------------------
    println!("[2] software baselines on one {W}x{H} frame");
    let frame = &seq[0];
    let k3 = conv::gaussian3x3();
    let k5 = conv::gaussian5x5();
    let t = Instant::now();
    let _ = software::conv_sw(frame, &k3, 3);
    let conv3_t = t.elapsed();
    let t = Instant::now();
    let _ = software::conv_sw(frame, &k5, 5);
    let conv5_t = t.elapsed();
    let t = Instant::now();
    let _ = software::median_sw(frame);
    let med_t = t.elapsed();
    let prog = dsl::parse::parse(include_str!("dsl/nlfilter.dsl"))?;
    let interp = dsl::Interp::new_window(&prog)?;
    let t = Instant::now();
    let _ = interp.run_frame(frame)?;
    let nl_t = t.elapsed();
    println!("    conv3x3 (vectorized)  : {:>10.2?}/frame", conv3_t);
    println!("    conv5x5 (vectorized)  : {:>10.2?}/frame", conv5_t);
    println!("    median  (vectorized)  : {:>10.2?}/frame", med_t);
    println!("    nlfilter (interpreted): {:>10.2?}/frame  <- the paper's bottleneck", nl_t);

    // the headline: hardware pixel-clock rate vs interpreted software at 1080p
    let px_1080 = (1920 * 1080) as f64;
    let nl_sw_1080 = 1.0 / (nl_t.as_secs_f64() * px_1080 / (W * H) as f64);
    let headline = T1080P.fpga_fps() / nl_sw_1080;
    println!(
        "\n    headline: nlfilter hardware {:.0} FPS vs software {:.3} FPS at 1080p -> {:.0}x (paper: ~810x)\n",
        T1080P.fpga_fps(),
        nl_sw_1080,
        headline
    );

    // --- 3. PJRT golden cross-check ----------------------------------------
    println!("[3] PJRT golden artifacts (JAX/Pallas AOT) vs the simulator");
    golden_crosscheck()?;

    println!("\nall layers compose: DSL -> netlist -> cycle sim == JAX/Pallas -> HLO -> PJRT");
    Ok(())
}

#[cfg(feature = "pjrt")]
fn golden_crosscheck() -> Result<()> {
    use fpspatial::filters::HwFilter;
    use fpspatial::fpcore::quantize;
    use fpspatial::runtime::Runtime;
    use fpspatial::video::Frame;

    match Runtime::new("artifacts") {
        Ok(rt) => {
            let gold = Frame::test_card(128, 96);
            let qgold = Frame {
                width: gold.width,
                height: gold.height,
                data: gold.data.iter().map(|&v| quantize(v, FMT)).collect(),
            };
            for kind in FilterKind::TABLE1 {
                let exe = rt.load_filter(kind.name(), Some("f16"), 96, 128)?;
                let kernel = match kind {
                    FilterKind::Conv3x3 => Some(conv::gaussian3x3()),
                    FilterKind::Conv5x5 => Some(conv::gaussian5x5()),
                    _ => None,
                };
                let got = exe.run(&gold, kernel.as_deref())?;
                // the plan's sequential oracle is the simulator reference
                let want = match kind {
                    FilterKind::Conv3x3 | FilterKind::Conv5x5 => {
                        let kq: Vec<f64> =
                            kernel.as_ref().unwrap().iter().map(|&v| quantize(v, FMT)).collect();
                        Pipeline::from_stages([HwFilter::with_kernel(kind, FMT, &kq)])
                            .compile(OpMode::Exact)?
                            .run_frame_sequential(&qgold)
                    }
                    _ => Pipeline::new()
                        .builtin(kind)
                        .format(FMT)
                        .compile(OpMode::Exact)?
                        .run_frame_sequential(&qgold),
                };
                let diff = got.max_abs_diff(&want);
                println!(
                    "    {:<9} max |sim - pjrt| = {}  {}",
                    kind.name(),
                    diff,
                    if diff == 0.0 { "BIT-EXACT" } else { "MISMATCH!" }
                );
                assert_eq!(diff, 0.0, "{} mismatch", kind.name());
            }
        }
        Err(e) => println!("    (skipped: {e:#} — run `make artifacts`)"),
    }
    Ok(())
}

/// Without the `pjrt` feature there is no XLA client to execute the
/// artifacts — sections 1 and 2 still run in full.
#[cfg(not(feature = "pjrt"))]
fn golden_crosscheck() -> Result<()> {
    println!("    (skipped: built without the `pjrt` feature — see `make artifacts`)");
    Ok(())
}
