//! Scenario: real-time salt-and-pepper denoising (§III-C's motivating
//! workload).
//!
//! Corrupts a test sequence with impulse noise, runs the hardware median
//! datapath at several custom-float widths, and reports PSNR improvement
//! and the precision-vs-resources tradeoff — the paper's core argument
//! that narrow custom floats are enough for imaging.
//!
//! Run: `cargo run --release --example denoise_median`

use anyhow::Result;
use fpspatial::filters::FilterKind;
use fpspatial::fpcore::format::FORMATS;
use fpspatial::fpcore::OpMode;
use fpspatial::pipeline::{ExecPlan, Pipeline};
use fpspatial::resources::{estimate, ZYBO_Z7_20};
use fpspatial::video::Frame;

fn main() -> Result<()> {
    let (w, h) = (320, 240);
    let clean = Frame::test_card(w, h);
    let noisy = {
        // impulse-corrupt 8% of pixels
        let mut rng = fpspatial::util::rng::Rng::new(77);
        Frame::from_fn(w, h, |x, y| {
            let r = rng.next_f64();
            if r < 0.04 {
                0.0
            } else if r < 0.08 {
                255.0
            } else {
                clean.get(x, y)
            }
        })
    };
    println!("salt-and-pepper denoising, {w}x{h}, 8% impulse noise");
    println!("noisy PSNR vs clean: {:.2} dB\n", noisy.psnr(&clean));
    println!(
        "{:<14} {:>10} {:>10} {:>8} {:>8} {:>8}",
        "format", "PSNR (dB)", "ΔPSNR", "LUTs", "FFs", "BRAM36"
    );

    for (key, fmt) in FORMATS {
        let plan =
            Pipeline::new().builtin(FilterKind::Median).format(fmt).compile(OpMode::Exact)?;
        let out = plan.session(ExecPlan::Batched)?.process(&noisy)?;
        let hw = &plan.stages()[0];
        let usage = estimate(&hw.netlist, Some((hw.geom, 1920)));
        println!(
            "{:<14} {:>10.2} {:>+10.2} {:>8} {:>8} {:>8.1}",
            format!("{fmt} ({key})"),
            out.psnr(&clean),
            out.psnr(&clean) - noisy.psnr(&clean),
            usage.luts,
            usage.ffs,
            usage.bram36,
        );
        if key == "f16" {
            out.save_pgm(std::env::temp_dir().join("denoised_f16.pgm"))?;
        }
    }
    println!(
        "\nfloat16(10,5) already recovers the image — the paper's \
         hardware-compactness argument.\n(Zybo budget: {} LUTs, {} FFs.)",
        ZYBO_Z7_20.luts, ZYBO_Z7_20.ffs
    );
    Ok(())
}
