# Fig. 16: the generic non-linear spatial filter of eq. 2,
#
#   f_zeta = f_alpha * min(f_beta, f_delta) / max(f_beta, f_delta)
#
# in float16(10,5).  Here f0 = f^alpha, f1 = f^beta, f2 = f^delta and
# f3 = f^phi.  The program is untimed: the compiler computes
# lambda(f1) = 15 and lambda(f2) = 9 and inserts the Delta = 6 delay
# registers at the CMP_and_SWAP automatically (the SIII-D walk-through);
# total latency 26 cycles.

use float(10, 5);

var float w[3][3], wp[3][3], pix_i, pix_o;
var float m0, m1, s0, s1, a0, f0;
var float m2, m3, l0, l1, a1, f1;
var float m4, f2, g1, g2, f3;

image_resolution(1920, 1080);

w = sliding_window(pix_i, 3, 3);

# w' = max(w, 1) guards the logs and the divide (fig. 16 lines 10-18)
wp[0][0] = max(w[0][0], 1);
wp[0][1] = max(w[0][1], 1);
wp[0][2] = max(w[0][2], 1);
wp[1][0] = max(w[1][0], 1);
wp[1][1] = max(w[1][1], 1);
wp[1][2] = max(w[1][2], 1);
wp[2][0] = max(w[2][0], 1);
wp[2][1] = max(w[2][1], 1);
wp[2][2] = max(w[2][2], 1);

# f^alpha = 0.5 * (sqrt(w00'*w02') + sqrt(w20'*w22'))
m0 = mult(wp[0][0], wp[0][2]);
m1 = mult(wp[2][0], wp[2][2]);
s0 = sqrt(m0);
s1 = sqrt(m1);
a0 = adder(s0, s1);
f0 = FP_RSH(a0) >> 1;

# f^beta = 8 * (log2(w01'*w21') + log2(w10'*w12'))
m2 = mult(wp[0][1], wp[2][1]);
m3 = mult(wp[1][0], wp[1][2]);
l0 = log2(m2);
l1 = log2(m3);
a1 = adder(l0, l1);
f1 = FP_LSH(a1) << 3;

# f^delta = 2^(0.0313 * w11')  (fig. 16 line 40)
m4 = mult(wp[1][1], 0.0313);
f2 = exp2(m4);

# f^phi = min/max ratio via CMP_and_SWAP + divide
[g1, g2] = cmp_and_swap(f1, f2);
f3 = div(g1, g2);

pix_o = mult(f0, f3);
