# 3x3 median filter (SIII-C, fig. 8) in float16(10,5).
#
# The median3x3 library macro expands to two Bose-Nelson SORT5
# networks over the diagonal+centre and cross footprints; the output
# is the mean of the two medians (adder + floating-point right
# shift).  Total latency 19 cycles, zero multipliers.

use float(10, 5);

var float w[3][3], pix_i, pix_o;

image_resolution(1920, 1080);

w = sliding_window(pix_i, 3, 3);

pix_o = median3x3(w);
