# Fig. 14: 3x3 convolution over the pixel stream in float16(10,5).
#
# Kernel = the Gaussian blur 1/16 * [1 2 1; 2 4 2; 1 2 1] — the same
# coefficients the built-in conv3x3 datapath uses, so this program
# lowers to a bit-identical netlist (9 constant multipliers feeding
# the recursive AdderTree(9); total latency 26 cycles).

use float(10, 5);

var float w[3][3], K[3][3], pix_i, pix_o;

image_resolution(1920, 1080);

w = sliding_window(pix_i, 3, 3);

K = [[0.0625, 0.125, 0.0625],
     [0.125, 0.25, 0.125],
     [0.0625, 0.125, 0.0625]];

pix_o = conv3x3(w, K);
