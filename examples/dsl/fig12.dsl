# Fig. 12: z = sqrt((x*y)/(x+y)) in float16(10,5).
#
# The canonical scalar program of SV: the compiler assigns
# lambda(m)=2, lambda(s)=6, inserts the Delta=4 delay on m at the
# divider, and reports a total latency of 18 cycles.

use float(10, 5);

input x, y;
output z;

var float x, y, m, s, d, z;

m = mult(x, y);
s = adder(x, y);
d = div(m, s);
z = sqrt(d);
