# 5x5 convolution over the pixel stream in float16(10,5).
#
# Kernel = the binomial 5x5 Gaussian ([1 4 6 4 1] outer product / 256),
# matching the built-in conv5x5 datapath: 25 constant multipliers into
# AdderTree(25) = AT(16) + AT(9); total latency 32 cycles.

use float(10, 5);

var float w[5][5], K[5][5], pix_i, pix_o;

image_resolution(1920, 1080);

w = sliding_window(pix_i, 5, 5);

K = [[0.00390625, 0.015625, 0.0234375, 0.015625, 0.00390625],
     [0.015625, 0.0625, 0.09375, 0.0625, 0.015625],
     [0.0234375, 0.09375, 0.140625, 0.09375, 0.0234375],
     [0.015625, 0.0625, 0.09375, 0.0625, 0.015625],
     [0.00390625, 0.015625, 0.0234375, 0.015625, 0.00390625]];

pix_o = conv5x5(w, K);
