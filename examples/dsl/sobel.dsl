# fp_sobel (SIV-B, eq. 3): gradient magnitude from two 3x3
# convolutions, pix_o = sqrt(conv(Kx)^2 + conv(Ky)^2), in
# float16(10,5).  Lowers to the built-in fp_sobel datapath: 18
# constant multipliers, two adder trees, two squaring multipliers,
# one adder and a square root; total latency 39 cycles.

use float(10, 5);

var float w[3][3], Kx[3][3], Ky[3][3];
var float gx, gy, gx2, gy2, g2s, pix_i, pix_o;

image_resolution(1920, 1080);

w = sliding_window(pix_i, 3, 3);

Kx = [[1.0, 0.0, -1.0],
      [2.0, 0.0, -2.0],
      [1.0, 0.0, -1.0]];
Ky = [[1.0, 2.0, 1.0],
      [0.0, 0.0, 0.0],
      [-1.0, -2.0, -1.0]];

gx = conv3x3(w, Kx);
gy = conv3x3(w, Ky);

gx2 = mult(gx, gx);
gy2 = mult(gy, gy);
g2s = adder(gx2, gy2);
pix_o = sqrt(g2s);
