//! Scenario: edge detection — floating-point Sobel (eq. 3) vs the
//! fixed-point HLS baseline of §IV-B.
//!
//! Runs both datapaths over a detailed frame, compares numerics, resource
//! usage, and poly-approx vs exact transcendental accuracy.
//!
//! Run: `cargo run --release --example sobel_edges`

use anyhow::Result;
use fpspatial::filters::{fixed, FilterKind};
use fpspatial::fpcore::format::FORMATS;
use fpspatial::fpcore::OpMode;
use fpspatial::pipeline::{ExecPlan, Pipeline};
use fpspatial::resources::{estimate, hls_sobel_usage, ZYBO_Z7_20};
use fpspatial::video::Frame;

fn main() -> Result<()> {
    let frame = Frame::test_card(320, 240);

    // fixed-point HLS-style baseline
    let t0 = std::time::Instant::now();
    let hls = fixed::sobel_fixed_frame(&frame);
    let hls_t = t0.elapsed();
    hls.save_pgm(std::env::temp_dir().join("sobel_hls.pgm"))?;

    println!("fp_sobel vs hls_sobel on a {}x{} test card\n", frame.width, frame.height);
    println!(
        "{:<14} {:>12} {:>12} {:>8} {:>6} {:>8}",
        "variant", "maxΔ vs hls", "maxΔ poly", "LUTs", "DSPs", "fits"
    );

    let hls_usage = hls_sobel_usage(1920);
    println!(
        "{:<14} {:>12} {:>12} {:>8} {:>6} {:>8}",
        "hls (q16.8)", "-", "-", hls_usage.luts, hls_usage.dsps,
        hls_usage.fits(ZYBO_Z7_20)
    );

    for (key, fmt) in FORMATS {
        // one plan per numeric mode (the plan fixes the operator model)
        let exact_plan =
            Pipeline::new().builtin(FilterKind::FpSobel).format(fmt).compile(OpMode::Exact)?;
        let poly_plan =
            Pipeline::new().builtin(FilterKind::FpSobel).format(fmt).compile(OpMode::Poly)?;
        let exact = exact_plan.session(ExecPlan::Batched)?.process(&frame)?;
        let poly = poly_plan.session(ExecPlan::Batched)?.process(&frame)?;
        let hw = &exact_plan.stages()[0];
        let usage = estimate(&hw.netlist, Some((hw.geom, 1920)));
        println!(
            "{:<14} {:>12.3} {:>12.4} {:>8} {:>6} {:>8}",
            format!("fp {key}"),
            exact.max_abs_diff(&hls),
            exact.max_abs_diff(&poly),
            usage.luts,
            usage.dsps,
            usage.fits(ZYBO_Z7_20)
        );
        if key == "f16" {
            exact.save_pgm(std::env::temp_dir().join("sobel_f16.pgm"))?;
        }
    }
    println!(
        "\nhls frame time (software model): {hls_t:.2?}; \
         fp_sobel ≤24-bit beats the HLS baseline on LUTs (paper §IV-B)."
    );
    Ok(())
}
