"""Layer 2: the filter pipelines as jittable jax functions.

Each entry point returns a function ``f(x[, k]) -> (y,)`` over f64 images,
built on the Pallas stencil kernels (Layer 1).  ``fmt=None`` builds the
native-f64 "software" variant (Table I software rows); a ``FloatFormat``
builds the custom-float variant whose numerics the Rust cycle simulator
reproduces bit-for-bit.

All functions are shape-specialized at lowering time (``aot.py``) — one
HLO artifact per (filter, format, resolution).
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402

from .formats import FloatFormat  # noqa: E402
from .kernels import stencil  # noqa: E402
from .kernels.quantize import quantize  # noqa: E402

#: Filters that take a runtime kernel-coefficient operand.
CONV_FILTERS = {"conv3x3": 3, "conv5x5": 5}
#: Fixed-function filters (x-only).
FIXED_FILTERS = ("median", "nlfilter", "sobel")
ALL_FILTERS = tuple(CONV_FILTERS) + FIXED_FILTERS


def build(filter_name: str, fmt: FloatFormat | None):
    """Return the jax function for `filter_name` in format `fmt`.

    conv filters: f(x:(H,W), k:(ksize*ksize,)) -> (y:(H,W),)
    fixed filters: f(x:(H,W)) -> (y:(H,W),)
    """
    if filter_name in CONV_FILTERS:

        def conv_fn(x, k):
            xq = x if fmt is None else quantize(x, fmt)
            kq = k if fmt is None else quantize(k, fmt)
            return (stencil.conv2d(xq, kq, fmt),)

        return conv_fn

    body = {
        "median": stencil.median3x3,
        "nlfilter": stencil.nlfilter,
        "sobel": stencil.sobel,
    }[filter_name]

    def fixed_fn(x):
        xq = x if fmt is None else quantize(x, fmt)
        return (body(xq, fmt),)

    return fixed_fn


def example_args(filter_name: str, h: int, w: int):
    """Shape specs used for AOT lowering."""
    x = jax.ShapeDtypeStruct((h, w), jnp.float64)
    if filter_name in CONV_FILTERS:
        ks = CONV_FILTERS[filter_name]
        return (x, jax.ShapeDtypeStruct((ks * ks,), jnp.float64))
    return (x,)
