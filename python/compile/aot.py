"""AOT lowering: every (filter x format x resolution) variant -> HLO text.

Interchange format is HLO *text*, not a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (the
version behind the rust `xla` 0.1.6 crate) rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Artifact sets (written to artifacts/, plus manifest.json):

  golden   — all 5 filters x 5 custom formats at a small resolution
             (default 96x128); the Rust cycle simulator is checked
             bit-for-bit against these through the PJRT runtime.
  software — the 4 Table-I filters + sobel in native f64 at the three paper
             resolutions (480p / 720p / 1080p); the vectorized software
             baseline rows of Table I.

Usage: python -m compile.aot [--out-dir ../artifacts] [--golden-only]
"""

import argparse
import json
import pathlib

import jax

jax.config.update("jax_enable_x64", True)

from jax._src.lib import xla_client as xc  # noqa: E402

from . import model  # noqa: E402
from .formats import FORMAT_ORDER, FORMATS  # noqa: E402

#: Table I resolutions (h, w).
RESOLUTIONS = {"480p": (480, 640), "720p": (720, 1280), "1080p": (1080, 1920)}

GOLDEN_SHAPE = (96, 128)


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (return_tuple=True so the
    rust side unwraps with to_tuple1)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_variant(filter_name: str, fmt_key: str | None, h: int, w: int) -> str:
    fmt = None if fmt_key is None else FORMATS[fmt_key]
    fn = model.build(filter_name, fmt)
    lowered = jax.jit(fn).lower(*model.example_args(filter_name, h, w))
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--golden-only", action="store_true", help="skip the full-resolution software set")
    ap.add_argument(
        "--golden-shape",
        default=f"{GOLDEN_SHAPE[0]}x{GOLDEN_SHAPE[1]}",
        help="HxW for the golden set",
    )
    args = ap.parse_args()
    out = pathlib.Path(args.out_dir)
    out.mkdir(parents=True, exist_ok=True)
    gh, gw = (int(v) for v in args.golden_shape.split("x"))

    manifest = []

    def emit(filter_name, fmt_key, h, w, tag):
        fmt_name = fmt_key or "soft"
        name = f"{filter_name}_{fmt_name}_{h}x{w}.hlo.txt"
        text = lower_variant(filter_name, fmt_key, h, w)
        (out / name).write_text(text)
        fmt = FORMATS.get(fmt_key) if fmt_key else None
        manifest.append(
            {
                "file": name,
                "filter": filter_name,
                "format": fmt_key,
                "mantissa": fmt.mantissa if fmt else None,
                "exponent": fmt.exponent if fmt else None,
                "height": h,
                "width": w,
                "set": tag,
            }
        )
        print(f"  wrote {name} ({len(text)} chars)")

    print(f"[aot] golden set @ {gh}x{gw}")
    for filter_name in model.ALL_FILTERS:
        for fmt_key in FORMAT_ORDER:
            emit(filter_name, fmt_key, gh, gw, "golden")

    if not args.golden_only:
        print("[aot] software baseline set (native f64)")
        for filter_name in model.ALL_FILTERS:
            for res, (h, w) in RESOLUTIONS.items():
                emit(filter_name, None, h, w, f"software-{res}")

    (out / "manifest.json").write_text(json.dumps(manifest, indent=2) + "\n")
    print(f"[aot] {len(manifest)} artifacts -> {out}/manifest.json")


if __name__ == "__main__":
    main()
