"""Row-tiled Pallas stencil kernels (Layer 1).

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the FPGA streams one
pixel per cycle through line buffers; on TPU the same locality is expressed
as a row-tile schedule — each grid step holds a (tile_h + K - 1)-row slab in
VMEM (the "line buffer" halo), computes the whole window reduction
vectorized across the tile, and writes a (tile_h, W) output block.  The
BlockSpec index_map is the HBM<->VMEM schedule the paper implements with
dual-port BRAMs.

``interpret=True`` everywhere: real-TPU lowering emits a Mosaic custom-call
the CPU PJRT plugin cannot execute (see /opt/xla-example/README.md).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..formats import FloatFormat
from . import ops


def pick_tile_h(h: int, target: int = 64) -> int:
    """Largest divisor of `h` that is <= target (VMEM-sized row tile)."""
    best = 1
    for d in range(1, min(h, target) + 1):
        if h % d == 0:
            best = d
    return best


def _stencil_call(xp, h: int, w: int, ksize: int, tile_h: int, body, extra_inputs=()):
    """Shared pallas_call wrapper.

    `xp` is the replicate-padded image (h + 2p, w + 2p); `body(planes, *ins)`
    receives the ksize*ksize shifted tile planes in raster order and returns
    the (tile_h, w) output tile.
    """
    p = ksize // 2
    nt = h // tile_h
    slab_h = tile_h + 2 * p

    def kernel(xp_ref, *refs):
        ins = [r[...] for r in refs[:-1]]
        o_ref = refs[-1]
        i = pl.program_id(0)
        # The slab: this tile's rows plus the halo — the line-buffer window.
        slab = pl.load(xp_ref, (pl.dslice(i * tile_h, slab_h), slice(None)))
        planes = [
            slab[r : r + tile_h, c : c + w] for r in range(ksize) for c in range(ksize)
        ]
        o_ref[...] = body(planes, *ins)

    in_specs = [pl.BlockSpec(xp.shape, lambda i: (0, 0))]
    for extra in extra_inputs:
        in_specs.append(pl.BlockSpec(extra.shape, lambda i: tuple(0 for _ in extra.shape)))
    return pl.pallas_call(
        kernel,
        grid=(nt,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((tile_h, w), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((h, w), xp.dtype),
        interpret=True,
    )(xp, *extra_inputs)


def _pad(x, ksize: int):
    return jnp.pad(x, ksize // 2, mode="edge")


def conv2d(x, k, fmt: FloatFormat | None, tile_h: int | None = None):
    """Linear convolution with a runtime-supplied flat kernel `k`
    (ksize*ksize,) — the paper's reconfigurable-coefficient datapath."""
    h, w = x.shape
    ksize = int(round(int(k.shape[0]) ** 0.5))
    tile_h = tile_h or pick_tile_h(h)

    def body(planes, kflat):
        kl = [kflat[i] for i in range(ksize * ksize)]
        return ops.conv_window(planes, kl, fmt)

    return _stencil_call(_pad(x, ksize), h, w, ksize, tile_h, body, (k,))


def median3x3(x, fmt: FloatFormat | None, tile_h: int | None = None):
    h, w = x.shape
    tile_h = tile_h or pick_tile_h(h)
    return _stencil_call(
        _pad(x, 3), h, w, 3, tile_h, lambda planes: ops.median_window(planes, fmt)
    )


def nlfilter(x, fmt: FloatFormat | None, tile_h: int | None = None):
    h, w = x.shape
    tile_h = tile_h or pick_tile_h(h)
    return _stencil_call(
        _pad(x, 3), h, w, 3, tile_h, lambda planes: ops.nlfilter_window(planes, fmt)
    )


def sobel(x, fmt: FloatFormat | None, tile_h: int | None = None):
    h, w = x.shape
    tile_h = tile_h or pick_tile_h(h)
    return _stencil_call(
        _pad(x, 3), h, w, 3, tile_h, lambda planes: ops.sobel_window(planes, fmt)
    )
