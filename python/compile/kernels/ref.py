"""Pure-jnp full-image oracle for every filter.

This is the correctness reference the Pallas kernels are tested against
(and, with ``fmt=None``, the scipy-equivalent vectorized software baseline
of Table I).  Border handling is *replicate* (nearest-pixel extension),
matching the window generator's default in ``rust/src/video/window.rs``.
"""

import jax.numpy as jnp

from ..formats import FloatFormat
from . import ops


def window_planes(x, ksize: int) -> list:
    """Replicate-pad `x` and return the ksize*ksize shifted planes in
    raster order: plane[r*ksize+c][y, x] == padded[y+r, x+c]."""
    p = ksize // 2
    xp = jnp.pad(x, p, mode="edge")
    h, w = x.shape
    return [xp[r : r + h, c : c + w] for r in range(ksize) for c in range(ksize)]


def conv2d(x, k, fmt: FloatFormat | None):
    """Linear convolution (correlation orientation, as eq. 1) with an
    H x W kernel `k` (2-D array), replicate borders, same-size output."""
    ksize = int(k.shape[0])
    w = window_planes(x, ksize)
    kflat = [k[i, j] for i in range(ksize) for j in range(ksize)]
    # NOTE: input/coefficient quantization is the L2 wrapper's job
    # (model.build) — ref and the pallas kernels receive identical values.
    return ops.conv_window(w, kflat, fmt)


def median3x3(x, fmt: FloatFormat | None):
    return ops.median_window(window_planes(x, 3), fmt)


def nlfilter(x, fmt: FloatFormat | None):
    return ops.nlfilter_window(window_planes(x, 3), fmt)


def sobel(x, fmt: FloatFormat | None):
    return ops.sobel_window(window_planes(x, 3), fmt)
