"""jnp emulation of custom float(m, e) rounding.

``quantize(x, fmt)`` rounds an f64 array to the nearest value representable
in ``float(fmt.mantissa, fmt.exponent)`` under the conventions of
``formats.FloatFormat`` (flush-to-zero subnormals, saturating overflow,
ties-to-even).  It is applied after *every* arithmetic operation in the
kernels to emulate the per-operator rounding the paper's RTL performs.

The algorithm is mirrored bit-for-bit by ``rust/src/fpcore/quantize.rs``;
both sides compute in IEEE doubles, so results agree exactly for
mantissa widths <= 50.
"""

import jax.numpy as jnp

from ..formats import FloatFormat


def quantize(x, fmt: FloatFormat):
    """Round ``x`` (f64) to the nearest float(m, e) value.

    NaNs propagate (the hardware never produces them: all kernels guard
    division/log arguments with max(., 1)).
    """
    m = fmt.mantissa
    a = jnp.abs(x)
    s = jnp.sign(x)
    if m <= 50:
        # a = mant * 2**exp with mant in [0.5, 1); normalized E = exp - 1.
        _, exp = jnp.frexp(a)
        e_unb = exp - 1
        # Scale so the mantissa occupies [2**m, 2**(m+1)), round ties-even
        # (jnp.round == rint), and scale back.  ldexp is exact.
        scaled = jnp.ldexp(a, m - e_unb)
        q = jnp.ldexp(jnp.round(scaled), e_unb - m)
    else:
        # m >= 52: an IEEE double cannot be narrowed further; clamp only.
        q = a
    # Flush subnormals to zero, saturate overflow to the max finite value.
    q = jnp.where(q < fmt.min_normal, 0.0, q)
    q = jnp.where(q > fmt.max_value, fmt.max_value, q)
    return s * q


def quantize_py(x: float, fmt: FloatFormat) -> float:
    """Pure-python scalar reference for `quantize` (used by tests)."""
    import math

    if math.isnan(x):
        return x
    s = -1.0 if x < 0 or (x == 0 and math.copysign(1, x) < 0) else 1.0
    a = abs(x)
    if a == 0:
        return 0.0 * s
    if fmt.mantissa <= 50:
        mant, exp = math.frexp(a)  # a = mant * 2**exp, mant in [0.5, 1)
        e_unb = exp - 1
        scaled = math.ldexp(a, fmt.mantissa - e_unb)
        rounded = _rint(scaled)  # round half to even
        try:
            q = math.ldexp(rounded, e_unb - fmt.mantissa)
        except OverflowError:  # rounding carried past DBL_MAX -> saturate
            q = math.inf
    else:
        q = a
    if q < fmt.min_normal:
        q = 0.0
    if q > fmt.max_value:
        q = fmt.max_value
    return s * q


def _rint(v: float) -> float:
    """Round half to even, like numpy rint."""
    import math

    f = math.floor(v)
    d = v - f
    if d > 0.5:
        return f + 1.0
    if d < 0.5:
        return f
    return f if (f % 2 == 0) else f + 1.0
