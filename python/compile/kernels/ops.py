"""Canonical quantized operators and window math shared by the oracle
(`ref.py`) and the Pallas kernels.

Every function here defines the *bit-level contract* mirrored by the Rust
layer (`rust/src/fpcore`, `rust/src/filters`): identical accumulation
order, identical CAS sequences, identical rounding points.  Changing any
order here breaks the sim-vs-PJRT bit-exactness tests.

``fmt=None`` disables quantization and yields the native-f64 "software"
baseline (the scipy-equivalent vectorized path of Table I).
"""

import jax.numpy as jnp

from ..formats import FloatFormat
from .quantize import quantize

# ---------------------------------------------------------------------------
# Quantized primitive ops.  Latencies (pipeline cycles, from the paper):
#   max=1  mul=2  add=6  div=7  sqrt=5  log2=5  exp2=6  shift=1  cas=2
# The latencies live in rust/src/fpcore/latency.rs; here only numerics.
# ---------------------------------------------------------------------------


def _q(x, fmt):
    return x if fmt is None else quantize(x, fmt)


def qadd(a, b, fmt: FloatFormat | None):
    return _q(a + b, fmt)


def qmul(a, b, fmt: FloatFormat | None):
    return _q(a * b, fmt)


def qdiv(a, b, fmt: FloatFormat | None):
    return _q(a / b, fmt)


def qsqrt(a, fmt: FloatFormat | None):
    return _q(jnp.sqrt(a), fmt)


def qlog2(a, fmt: FloatFormat | None):
    return _q(jnp.log2(a), fmt)


def qexp2(a, fmt: FloatFormat | None):
    return _q(jnp.exp2(a), fmt)


def qmax1(a, fmt: FloatFormat | None):
    """max(a, 1) — guards log/div inputs (eq. 2). Exact, no rounding."""
    return jnp.maximum(a, 1.0)


def qrsh(a, n: int, fmt: FloatFormat | None):
    """Floating-point right shift: exponent -= n, i.e. a / 2**n (exact in
    f64; quantize handles subnormal flush at the format boundary)."""
    return _q(a * (2.0**-n), fmt)


def qlsh(a, n: int, fmt: FloatFormat | None):
    """Floating-point left shift: exponent += n, i.e. a * 2**n."""
    return _q(a * (2.0**n), fmt)


def cas(a, b):
    """CMP_and_SWAP: returns (min, max) — swaps the pair if a > b.

    Pure comparison/selection: exact in any format, never rounds.
    """
    return jnp.minimum(a, b), jnp.maximum(a, b)


# ---------------------------------------------------------------------------
# Adder tree — §III-B design rule.
# AdderTree(N): N0 = 2**floor(log2 N) (pairwise tree); the remaining
# N - N0 inputs form AdderTree(N - N0) recursively; the two results are
# added last.  Latency = L_ADD * ceil(log2 N).
# ---------------------------------------------------------------------------


def adder_tree(terms: list, fmt: FloatFormat | None):
    """Sum `terms` in the paper's canonical adder-tree order."""
    n = len(terms)
    assert n >= 1
    if n == 1:
        return terms[0]
    n0 = 1 << (n.bit_length() - 1)  # largest power of two <= n
    if n0 == n:
        # full pairwise tree, stage by stage
        level = terms
        while len(level) > 1:
            level = [qadd(level[i], level[i + 1], fmt) for i in range(0, len(level), 2)]
        return level[0]
    left = adder_tree(terms[:n0], fmt)
    right = adder_tree(terms[n0:], fmt)
    return qadd(left, right, fmt)


# ---------------------------------------------------------------------------
# Bose-Nelson SORT5 (fig. 7): 9 CMP_and_SWAP in 6 pipeline stages.
# The median of the 5 inputs is element 2 of the sorted output.
# ---------------------------------------------------------------------------

#: The canonical CAS sequence; mirrored by rust/src/filters/sorting.rs.
SORT5_CAS = [(0, 1), (3, 4), (2, 4), (2, 3), (1, 4), (0, 3), (0, 2), (1, 3), (1, 2)]

#: Pipeline stages for SORT5 (pairs that run concurrently) — 6 stages.
SORT5_STAGES = [
    [(0, 1), (3, 4)],
    [(2, 4)],
    [(2, 3), (1, 4)],
    [(0, 3)],
    [(0, 2), (1, 3)],
    [(1, 2)],
]

#: Footprints of the two SORT5 networks in the 3x3 window (fig. 8):
#: left network = diagonal + centre, right network = cross.
MEDIAN_FOOTPRINT_A = [0, 2, 4, 6, 8]  # w00 w02 w11 w20 w22
MEDIAN_FOOTPRINT_B = [1, 3, 4, 5, 7]  # w01 w10 w11 w12 w21


def sort5(vals: list):
    """Apply the Bose-Nelson CAS sequence; returns the sorted 5-list."""
    v = list(vals)
    for i, j in SORT5_CAS:
        v[i], v[j] = cas(v[i], v[j])
    return v


# ---------------------------------------------------------------------------
# Window compute functions.  Input: `w`, the list of H*W shifted planes in
# raster order (w[r*W + c] == pixel (y+r-p, x+c-p) under replicate padding).
# Output: the filtered plane.  These run unchanged on full images (ref) and
# on VMEM tiles (pallas kernels).
# ---------------------------------------------------------------------------


def conv_window(w: list, k, fmt: FloatFormat | None):
    """Linear convolution: per-pixel products (raster order) + adder tree.

    `k` is a flat list/array of H*W kernel coefficients (already format
    values).  The products are quantized individually (one DSP each in the
    RTL), then accumulated by `adder_tree`.
    """
    prods = [qmul(w[i], k[i], fmt) for i in range(len(w))]
    return adder_tree(prods, fmt)


def median_window(w: list, fmt: FloatFormat | None):
    """Median filter (fig. 8): mean of the medians of two SORT5 networks."""
    med_a = sort5([w[i] for i in MEDIAN_FOOTPRINT_A])[2]
    med_b = sort5([w[i] for i in MEDIAN_FOOTPRINT_B])[2]
    total = qadd(med_a, med_b, fmt)
    return qrsh(total, 1, fmt)  # divide by two: exponent decrement


def nlfilter_window(w: list, fmt: FloatFormat | None):
    """The generic non-linear filter of eq. 2 / fig. 16.

    f_alpha = 0.5 * (sqrt(w00'*w02') + sqrt(w20'*w22'))
    f_beta  = 8   * (log2(w01'*w21') + log2(w10'*w12'))
    f_delta = 2 ** (0.0313 * w11')          (fig. 16, line 40)
    f_zeta  = f_alpha * min(f_beta, f_delta) / max(f_beta, f_delta)
    where x' = max(x, 1).
    """
    wp = [qmax1(x, fmt) for x in w]
    w00, w01, w02, w10, w11, w12, w20, w21, w22 = wp

    m0 = qmul(w00, w02, fmt)
    m1 = qmul(w20, w22, fmt)
    s0 = qsqrt(m0, fmt)
    s1 = qsqrt(m1, fmt)
    a0 = qadd(s0, s1, fmt)
    f_alpha = qrsh(a0, 1, fmt)  # * 0.5

    m2 = qmul(w01, w21, fmt)
    m3 = qmul(w10, w12, fmt)
    l0 = qlog2(m2, fmt)
    l1 = qlog2(m3, fmt)
    a1 = qadd(l0, l1, fmt)
    f_beta = qlsh(a1, 3, fmt)  # * 8

    from .quantize import quantize_py

    c = 0.0313 if fmt is None else quantize_py(0.0313, fmt)
    m4 = qmul(w11, c, fmt)
    f_delta = qexp2(m4, fmt)

    g1, g2 = cas(f_beta, f_delta)  # g1 = min, g2 = max
    g = qdiv(g1, g2, fmt)
    return qmul(f_alpha, g, fmt)


#: Sobel kernels (eq. 3).
SOBEL_KX = [1.0, 0.0, -1.0, 2.0, 0.0, -2.0, 1.0, 0.0, -1.0]
SOBEL_KY = [1.0, 2.0, 1.0, 0.0, 0.0, 0.0, -1.0, -2.0, -1.0]


def sobel_window(w: list, fmt: FloatFormat | None):
    """fp_sobel (eq. 3): sqrt(conv(Kx)^2 + conv(Ky)^2)."""
    gx = conv_window(w, SOBEL_KX, fmt)
    gy = conv_window(w, SOBEL_KY, fmt)
    gx2 = qmul(gx, gx, fmt)
    gy2 = qmul(gy, gy, fmt)
    return qsqrt(qadd(gx2, gy2, fmt), fmt)
