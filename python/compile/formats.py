"""Custom floating-point formats float(m, e) from the paper.

A format float(m, e) has 1 sign bit, an m-bit mantissa (fraction) and an
e-bit exponent, bias = 2**(e-1) - 1.  Encoding conventions (mirrored
bit-for-bit by rust/src/fpcore/):

  * exponent field 0 encodes zero; subnormals are flushed to zero,
  * the all-ones exponent is a *normal* exponent (no inf/NaN encodings —
    FPGA datapaths saturate), overflow saturates to the largest finite
    value (2 - 2**-m) * 2**emax,
  * rounding is round-to-nearest, ties-to-even.

The five widths evaluated in the paper (fig. 11):

  float16(10, 5), float24(16, 7), float32(23, 8), float48(39, 8),
  float64(53, 10).

For m >= 52 the mantissa cannot be narrowed inside an IEEE double, so
quantization degenerates to range clamping only (documented in DESIGN.md).
"""

from dataclasses import dataclass


@dataclass(frozen=True)
class FloatFormat:
    """A custom float(m, e) format: m mantissa bits, e exponent bits."""

    mantissa: int
    exponent: int

    @property
    def bias(self) -> int:
        return 2 ** (self.exponent - 1) - 1

    @property
    def emin(self) -> int:
        """Smallest normal (unbiased) exponent; field value 1."""
        return 1 - self.bias

    @property
    def emax(self) -> int:
        """Largest (unbiased) exponent; the all-ones field is normal."""
        return 2**self.exponent - 1 - self.bias

    @property
    def width(self) -> int:
        return 1 + self.mantissa + self.exponent

    @property
    def max_value(self) -> float:
        return (2.0 - 2.0**-self.mantissa) * 2.0**self.emax

    @property
    def min_normal(self) -> float:
        return 2.0**self.emin

    @property
    def name(self) -> str:
        return f"m{self.mantissa}e{self.exponent}"

    def __str__(self) -> str:
        return f"float{self.width}({self.mantissa},{self.exponent})"


#: The paper's five evaluated formats (fig. 11), keyed by total width.
FORMATS = {
    "f16": FloatFormat(10, 5),
    "f24": FloatFormat(16, 7),
    "f32": FloatFormat(23, 8),
    "f48": FloatFormat(39, 8),
    "f64": FloatFormat(53, 10),
}

#: Order used for fig. 11 sweeps.
FORMAT_ORDER = ["f16", "f24", "f32", "f48", "f64"]
