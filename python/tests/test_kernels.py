"""Pallas kernels vs pure-jnp oracle (ref.py) and vs scipy.

The Pallas row-tiled kernels must agree with the full-image oracle exactly
(same op order, same rounding points), and the fmt=None oracle must agree
with scipy's convolve2d/medfilt2d up to f64 reassociation error.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.formats import FORMAT_ORDER, FORMATS
from compile.kernels import ops, ref, stencil

RNG = np.random.default_rng(7)


def rand_img(h, w, lo=0.0, hi=255.0):
    return jnp.asarray(RNG.uniform(lo, hi, (h, w)))


def rand_kernel(ks):
    return jnp.asarray(RNG.uniform(-2.0, 2.0, (ks, ks)))


FMT_KEYS = FORMAT_ORDER + [None]


def assert_match(got, want, fmt):
    """Quantized formats must match bit-for-bit (the Rust sim contract);
    native f64 allows XLA FMA-contraction reassociation (~1e-13)."""
    got, want = np.asarray(got), np.asarray(want)
    if fmt is None:
        np.testing.assert_allclose(got, want, rtol=1e-10, atol=1e-10)
    else:
        np.testing.assert_array_equal(got, want)



class TestConv:
    @pytest.mark.parametrize("fmt_key", FMT_KEYS)
    @pytest.mark.parametrize("ksize", [3, 5])
    def test_pallas_matches_ref(self, fmt_key, ksize):
        fmt = FORMATS[fmt_key] if fmt_key else None
        x = rand_img(24, 32)
        k = rand_kernel(ksize)
        want = ref.conv2d(x, k, fmt)
        got = stencil.conv2d(x, k.reshape(-1), fmt, tile_h=8)
        assert_match(got, want, fmt)

    def test_identity_kernel(self):
        x = rand_img(16, 16)
        k = jnp.zeros((3, 3)).at[1, 1].set(1.0)
        got = ref.conv2d(x, k, None)
        np.testing.assert_allclose(np.asarray(got), np.asarray(x), rtol=1e-12)

    def test_vs_scipy(self):
        from scipy.ndimage import correlate

        x = rand_img(20, 28)
        k = rand_kernel(3)
        want = correlate(np.asarray(x), np.asarray(k), mode="nearest")
        got = np.asarray(ref.conv2d(x, k, None))
        np.testing.assert_allclose(got, want, rtol=1e-10)

    def test_vs_scipy_5x5(self):
        from scipy.ndimage import correlate

        x = rand_img(20, 28)
        k = rand_kernel(5)
        want = correlate(np.asarray(x), np.asarray(k), mode="nearest")
        got = np.asarray(ref.conv2d(x, k, None))
        np.testing.assert_allclose(got, want, rtol=1e-10)

    @given(
        h=st.integers(6, 40),
        w=st.integers(6, 40),
        fmt_key=st.sampled_from(["f16", "f32", None]),
    )
    @settings(max_examples=20, deadline=None)
    def test_shape_sweep(self, h, w, fmt_key):
        fmt = FORMATS[fmt_key] if fmt_key else None
        x = rand_img(h, w)
        k = rand_kernel(3)
        want = ref.conv2d(x, k, fmt)
        got = stencil.conv2d(x, k.reshape(-1), fmt)
        assert got.shape == (h, w)
        assert_match(got, want, fmt)

    def test_quantized_output_is_representable(self):
        from compile.kernels.quantize import quantize

        fmt = FORMATS["f16"]
        x = rand_img(12, 12)
        k = rand_kernel(3)
        y = ref.conv2d(x, k, fmt)
        np.testing.assert_array_equal(np.asarray(quantize(y, fmt)), np.asarray(y))


class TestMedian:
    @pytest.mark.parametrize("fmt_key", FMT_KEYS)
    def test_pallas_matches_ref(self, fmt_key):
        fmt = FORMATS[fmt_key] if fmt_key else None
        x = rand_img(24, 32)
        want = ref.median3x3(x, fmt)
        got = stencil.median3x3(x, fmt, tile_h=8)
        assert_match(got, want, fmt)

    def test_sort5_sorts(self):
        for _ in range(50):
            vals = RNG.uniform(-10, 10, 5)
            out = [float(v) for v in ops.sort5([jnp.float64(v) for v in vals])]
            assert out == sorted(vals.tolist())

    def test_sort5_cas_count(self):
        """Paper: Bose-Nelson sorts 5 inputs with 9 CAS in 6 stages."""
        assert len(ops.SORT5_CAS) == 9
        assert len(ops.SORT5_STAGES) == 6
        assert sorted(p for s in ops.SORT5_STAGES for p in s) == sorted(ops.SORT5_CAS)

    def test_constant_image(self):
        x = jnp.full((10, 10), 7.0)
        got = ref.median3x3(x, None)
        np.testing.assert_allclose(np.asarray(got), 7.0)

    def test_impulse_rejected(self):
        """A single hot pixel must be removed by the median."""
        x = jnp.zeros((11, 11)).at[5, 5].set(1000.0)
        got = np.asarray(ref.median3x3(x, None))
        assert got[5, 5] == 0.0

    def test_footprints(self):
        """The two SORT5 footprints cover the full cross + diagonals."""
        assert ops.MEDIAN_FOOTPRINT_A == [0, 2, 4, 6, 8]
        assert ops.MEDIAN_FOOTPRINT_B == [1, 3, 4, 5, 7]
        assert sorted(set(ops.MEDIAN_FOOTPRINT_A + ops.MEDIAN_FOOTPRINT_B)) == list(range(9))


class TestNlfilter:
    @pytest.mark.parametrize("fmt_key", FMT_KEYS)
    def test_pallas_matches_ref(self, fmt_key):
        fmt = FORMATS[fmt_key] if fmt_key else None
        x = rand_img(24, 32)
        want = ref.nlfilter(x, fmt)
        got = stencil.nlfilter(x, fmt, tile_h=8)
        assert_match(got, want, fmt)

    def test_matches_equation2_scalar(self):
        """Cross-check one interior pixel against a literal transcription
        of eq. 2 / fig. 16 in plain python."""
        import math

        x = rand_img(8, 8)
        xn = np.asarray(x)
        y = np.asarray(ref.nlfilter(x, None))
        r, c = 4, 4
        w = {(i, j): max(xn[r - 1 + i, c - 1 + j], 1.0) for i in range(3) for j in range(3)}
        f_alpha = 0.5 * (
            math.sqrt(w[0, 0] * w[0, 2]) + math.sqrt(w[2, 0] * w[2, 2])
        )
        f_beta = 8.0 * (
            math.log2(w[0, 1] * w[2, 1]) + math.log2(w[1, 0] * w[1, 2])
        )
        f_delta = 2.0 ** (0.0313 * w[1, 1])
        g1, g2 = min(f_beta, f_delta), max(f_beta, f_delta)
        want = f_alpha * (g1 / g2)
        np.testing.assert_allclose(y[r, c], want, rtol=1e-9)

    def test_output_positive(self):
        x = rand_img(16, 16)
        y = np.asarray(ref.nlfilter(x, FORMATS["f16"]))
        assert (y >= 0).all()
        assert np.isfinite(y).all()

    def test_guard_handles_zeros(self):
        """max(., 1) guard: all-zero image must not produce NaN/inf."""
        x = jnp.zeros((8, 8))
        y = np.asarray(ref.nlfilter(x, FORMATS["f16"]))
        assert np.isfinite(y).all()


class TestSobel:
    @pytest.mark.parametrize("fmt_key", FMT_KEYS)
    def test_pallas_matches_ref(self, fmt_key):
        fmt = FORMATS[fmt_key] if fmt_key else None
        x = rand_img(24, 32)
        want = ref.sobel(x, fmt)
        got = stencil.sobel(x, fmt, tile_h=8)
        assert_match(got, want, fmt)

    def test_flat_image_zero_gradient(self):
        x = jnp.full((12, 12), 50.0)
        y = np.asarray(ref.sobel(x, None))
        np.testing.assert_allclose(y, 0.0, atol=1e-9)

    def test_vertical_edge_detected(self):
        x = jnp.concatenate([jnp.zeros((10, 5)), jnp.full((10, 5), 255.0)], axis=1)
        y = np.asarray(ref.sobel(x, None))
        assert y[5, 4] > 100.0  # strong response at the edge
        assert y[5, 1] == 0.0  # flat region

    def test_sobel_kernels_match_eq3(self):
        assert ops.SOBEL_KX == [1.0, 0.0, -1.0, 2.0, 0.0, -2.0, 1.0, 0.0, -1.0]
        assert ops.SOBEL_KY == [1.0, 2.0, 1.0, 0.0, 0.0, 0.0, -1.0, -2.0, -1.0]


class TestAdderTree:
    @pytest.mark.parametrize("n", list(range(1, 26)))
    def test_sums_correctly(self, n):
        vals = RNG.uniform(-5, 5, n)
        got = float(ops.adder_tree([jnp.float64(v) for v in vals], None))
        np.testing.assert_allclose(got, vals.sum(), rtol=1e-12)

    def test_decomposition_order_9(self):
        """AdderTree(9) = AdderTree(8) + last term (paper fig. 4/5)."""
        vals = [jnp.float64(v) for v in RNG.uniform(0, 1, 9)]
        t8 = ops.adder_tree(vals[:8], None)
        want = float(t8 + vals[8])
        got = float(ops.adder_tree(vals, None))
        assert got == want

    def test_decomposition_order_25(self):
        """AdderTree(25) = AdderTree(16) + AdderTree(9)."""
        vals = [jnp.float64(v) for v in RNG.uniform(0, 1, 25)]
        want = float(ops.adder_tree(vals[:16], None) + ops.adder_tree(vals[16:], None))
        got = float(ops.adder_tree(vals, None))
        assert got == want


class TestModelBuild:
    @pytest.mark.parametrize("filter_name", ["conv3x3", "conv5x5", "median", "nlfilter", "sobel"])
    def test_jit_and_shapes(self, filter_name):
        from compile import model

        fn = jax.jit(model.build(filter_name, FORMATS["f16"]))
        x = rand_img(16, 16)
        if filter_name in model.CONV_FILTERS:
            ks = model.CONV_FILTERS[filter_name]
            (y,) = fn(x, jnp.ones(ks * ks) / (ks * ks))
        else:
            (y,) = fn(x)
        assert y.shape == x.shape

    def test_lowering_emits_hlo_text(self):
        from compile import aot

        text = aot.lower_variant("median", "f16", 16, 16)
        assert "HloModule" in text
        assert "f64" in text
