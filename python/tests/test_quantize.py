"""Quantization contract tests: jnp emulation vs pure-python bit reference.

These pin down the exact rounding semantics the Rust fpcore mirrors.
"""

import math

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.formats import FORMAT_ORDER, FORMATS, FloatFormat
from compile.kernels.quantize import quantize, quantize_py

F16 = FORMATS["f16"]


def q1(x, fmt):
    return float(quantize(jnp.float64(x), fmt))


class TestFormats:
    def test_widths(self):
        assert [FORMATS[k].width for k in FORMAT_ORDER] == [16, 24, 32, 48, 64]

    def test_f16_params(self):
        assert F16.bias == 15
        assert F16.emin == -14
        assert F16.emax == 16
        assert F16.max_value == (2 - 2**-10) * 2.0**16

    def test_f64_params(self):
        f = FORMATS["f64"]
        assert f.bias == 511
        assert f.width == 64


class TestQuantizeBasics:
    @pytest.mark.parametrize("fmt_key", FORMAT_ORDER)
    def test_zero_one_identity(self, fmt_key):
        fmt = FORMATS[fmt_key]
        assert q1(0.0, fmt) == 0.0
        assert q1(1.0, fmt) == 1.0
        assert q1(-1.0, fmt) == -1.0
        assert q1(2.0, fmt) == 2.0
        assert q1(1.5, fmt) == 1.5

    def test_rounding_f16(self):
        # 1 + 2^-11 is exactly halfway between 1 and 1 + 2^-10 -> ties to even -> 1
        assert q1(1.0 + 2.0**-11, F16) == 1.0
        # 1 + 3*2^-11 is halfway between 1+2^-10 and 1+2^-9 -> ties to even -> 1+2^-9
        assert q1(1.0 + 3 * 2.0**-11, F16) == 1.0 + 2.0**-9
        # just above the halfway point rounds up
        assert q1(1.0 + 2.0**-11 + 2.0**-30, F16) == 1.0 + 2.0**-10

    def test_overflow_saturates(self):
        assert q1(1e30, F16) == F16.max_value
        assert q1(-1e30, F16) == -F16.max_value

    def test_subnormal_flush(self):
        tiny = 2.0**-20  # below 2^-14 = min normal of float16(10,5)
        assert q1(tiny, F16) == 0.0
        assert q1(-tiny, F16) == 0.0
        assert q1(F16.min_normal, F16) == F16.min_normal

    def test_mantissa_carry(self):
        # 1.9999... rounds up to 2.0 (exponent carry)
        assert q1(2.0 - 2.0**-12, F16) == 2.0

    def test_nan_propagates(self):
        assert math.isnan(q1(float("nan"), F16))

    def test_m53_clamp_only(self):
        f = FORMATS["f64"]
        x = 1.0 + 2.0**-52
        assert q1(x, f) == x  # cannot narrow below double

    def test_idempotent(self):
        for v in [0.1, 3.14159, 255.0, 1e-4, 7.5, 1e4]:
            q = q1(v, F16)
            assert q1(q, F16) == q


class TestVsPythonReference:
    @pytest.mark.parametrize("fmt_key", ["f16", "f24", "f32", "f48"])
    def test_grid_agrees(self, fmt_key):
        fmt = FORMATS[fmt_key]
        rng = np.random.default_rng(42)
        xs = np.concatenate(
            [
                rng.uniform(-300, 300, 500),
                rng.uniform(-1e-5, 1e-5, 200),
                rng.uniform(-1e6, 1e6, 200),
                np.array([0.0, 1.0, -1.0, 0.5, 255.0, 2.0**-14, 2.0**16]),
            ]
        )
        got = np.asarray(quantize(jnp.asarray(xs), fmt))
        want = np.array([quantize_py(float(v), fmt) for v in xs])
        np.testing.assert_array_equal(got, want)

    @given(st.floats(allow_nan=False, allow_infinity=False, width=64))
    @settings(max_examples=300, deadline=None)
    def test_hypothesis_agrees_f16(self, x):
        got = q1(x, F16)
        want = quantize_py(x, F16)
        assert got == want or (math.isnan(got) and math.isnan(want))

    @given(st.floats(min_value=-1e5, max_value=1e5, allow_nan=False))
    @settings(max_examples=200, deadline=None)
    def test_error_bound(self, x):
        """|q(x) - x| <= ulp/2 for in-range values (relative 2^-11 for m=10)."""
        q = q1(x, F16)
        if abs(x) < F16.min_normal:
            assert q == 0.0 or abs(q) == F16.min_normal
        else:
            assert abs(q - x) <= abs(x) * 2.0**-11 + 1e-300

    @given(
        st.floats(min_value=1e-3, max_value=1e4),
        st.sampled_from(["f16", "f24", "f32"]),
    )
    @settings(max_examples=200, deadline=None)
    def test_monotone(self, x, fmt_key):
        fmt = FORMATS[fmt_key]
        assert q1(x * 1.001, fmt) >= q1(x, fmt)


class TestExhaustiveF16:
    def test_all_f16_values_are_fixed_points(self):
        """Every encodable float16(10,5) value must quantize to itself."""
        f = F16
        vals = []
        for e_field in range(1, 2**f.exponent):
            e = e_field - f.bias
            for m_field in range(0, 2**f.mantissa, 37):  # stride keeps runtime sane
                v = (1.0 + m_field * 2.0**-f.mantissa) * 2.0**e
                vals.append(v)
                vals.append(-v)
        arr = np.array(vals)
        got = np.asarray(quantize(jnp.asarray(arr), f))
        np.testing.assert_array_equal(got, arr)
